"""Quickstart: the paper's headline workflow through the unified front door.

Define a DE once in plain component-style jnp; `solve_ensemble` dispatches ANY
registered method (`repro.core.methods` — explicit RK like "tsit5", the stiff
"rosenbrock23" with batched-LU W-solves, or SDE steppers like "em") through
ANY execution strategy (`ensemble="array" | "vmap" | "kernel"`) and backend
(`backend="xla" | "pallas"`):

    from repro.core import EnsembleProblem, solve_ensemble_local
    res = solve_ensemble_local(ens, alg="tsit5",        ensemble="kernel")
    res = solve_ensemble_local(ens, alg="rosenbrock23", ensemble="kernel",
                               backend="pallas")        # stiff, fused kernel
    res = solve_ensemble_local(sde_ens, alg="em", dt0=1e-3, seed=7)

Every combination returns the same `EnsembleResult`; on the Pallas backend
`lane_tile=None` sizes the trajectory tile from the paper's §5.2 VMEM formula.
Below: a 10k-member Lorenz parameter ensemble three ways (array / vmap /
fused-kernel) — identical answers, very different work — then the stiff and
SDE families through the same front door.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax

jax.config.update("jax_enable_x64", True)  # the stiff example below is f64

import jax.numpy as jnp

from repro.core import EnsembleProblem, ODEProblem, SDEProblem
from repro.core.ensemble import solve_ensemble_local


def lorenz(u, p, t):
    s, r, b = p[0], p[1], p[2]
    return jnp.stack([s * (u[1] - u[0]),
                      r * u[0] - u[1] - u[0] * u[2],
                      u[0] * u[1] - b * u[2]])


prob = ODEProblem(lorenz, jnp.asarray([1.0, 0.0, 0.0], jnp.float32),
                  jnp.asarray([10.0, 21.0, 8 / 3], jnp.float32), (0.0, 1.0))
N = 10_000
rho = jnp.linspace(0.0, 21.0, N, dtype=jnp.float32)
ps = jnp.stack([jnp.full((N,), 10.0), rho, jnp.full((N,), 8 / 3)], axis=1)
ens = EnsembleProblem(prob, N, ps=ps)

saveat = jnp.linspace(0.0, 1.0, 11, dtype=jnp.float32)
for strategy in ("array", "vmap", "kernel"):
    t0 = time.perf_counter()
    res = solve_ensemble_local(ens, alg="tsit5", ensemble=strategy,
                               t0=0.0, tf=1.0, dt0=1e-3, saveat=saveat,
                               rtol=1e-6, atol=1e-6, lane_tile=1024)
    jax.block_until_ready(res.u_final)
    dt = time.perf_counter() - t0
    print(f"{strategy:>7}: {dt:7.2f}s  (incl. compile)   "
          f"RHS evals = {int(res.nf):>10,}   "
          f"u_final[0] = {res.u_final[0]}")
print("\nSame physics, same answers — the kernel strategy does per-trajectory"
      "\nadaptive stepping with tile-local termination (paper §5.2), the"
      "\narray strategy lock-steps the whole ensemble (paper §5.1).")

# --- or let the autotuner pick: ensemble="auto" ----------------------------
# First sight of a configuration micro-benchmarks the pruned candidate set
# (vmap/array/kernel x xla/pallas x lane-tile ladder) on a reduced copy of
# THIS problem and persists the winner to ~/.cache/repro/autotune.json
# (REPRO_AUTOTUNE_CACHE overrides; REPRO_AUTOTUNE=0 disables).  Warm cache
# = a dictionary lookup; the solve is bitwise-identical to explicitly
# dispatching the winner.  See docs/architecture.md "Autotuned dispatch".
t0 = time.perf_counter()
res = solve_ensemble_local(ens, alg="tsit5", ensemble="auto",
                           t0=0.0, tf=1.0, dt0=1e-3, saveat=saveat,
                           rtol=1e-6, atol=1e-6)
jax.block_until_ready(res.u_final)
print(f"   auto: {time.perf_counter() - t0:7.2f}s  (incl. first-sight "
      f"tuning; cached for next time)   u_final[0] = {res.u_final[0]}")

# --- stiff family, same front door: W = I - γh·J solved by batched LU -------
vdp = ODEProblem(lambda u, p, t: jnp.stack(
    [u[1], p[0] * ((1.0 - u[0] ** 2) * u[1]) - u[0]]),
    jnp.asarray([2.0, 0.0], jnp.float64), jnp.asarray([10.0], jnp.float64),
    (0.0, 1.0))
mus = jnp.linspace(5.0, 20.0, 64, dtype=jnp.float64)
stiff = EnsembleProblem(vdp, 64, ps=mus[:, None])
res = solve_ensemble_local(stiff, alg="rosenbrock23", ensemble="kernel",
                           dt0=1e-3, rtol=1e-6, atol=1e-6)
print(f"\nrosenbrock23 kernel: {int(res.naccept.sum()):,} accepted steps, "
      f"u_final[0] = {res.u_final[0]}")

# --- SDE family, same front door: counter-RNG Euler-Maruyama ---------------
gbm = SDEProblem(lambda u, p, t: p[0] * u, lambda u, p, t: p[1] * u,
                 jnp.asarray([0.1] * 3, jnp.float32),
                 jnp.asarray([1.5, 0.1], jnp.float32), (0.0, 1.0))
sde_ens = EnsembleProblem(gbm, 4096)
res = solve_ensemble_local(sde_ens, alg="em", ensemble="kernel", dt0=1e-3,
                           save_every=1000, seed=7)
print(f"em kernel: E[X(1)] = {float(res.u_final[:, 0].mean()):.4f} "
      f"(exact {0.1 * jnp.exp(1.5):.4f})")

# --- SDE with events + adaptive dt --------------------------------------
# Barrier-hitting with per-trajectory adaptive steps: each path integrates
# with its own error-controlled dt and terminates the moment it crosses the
# barrier; t_final records the located hitting time.  The default error
# estimator is em's EMBEDDED PAIR (EM vs drift-tamed Milstein — one stepper
# pass per attempt); error_est="doubling" selects step doubling (~3x the
# stepper cost) for A/B comparison.  Either way the noise is the
# rejection-safe virtual Brownian tree, so trajectories are
# bitwise-identical on every strategy/backend.
from repro.core import Event

barrier = Event(condition=lambda u, p, t: u[0] - 0.25, terminal=True,
                direction=1)
gbm64 = SDEProblem(lambda u, p, t: p[0] * u, lambda u, p, t: p[1] * u,
                   jnp.asarray([0.1] * 3, jnp.float64),
                   jnp.asarray([1.5, 0.3], jnp.float64), (0.0, 1.0))
hit_ens = EnsembleProblem(gbm64, 512)
res = solve_ensemble_local(hit_ens, alg="em", ensemble="kernel",
                           backend="xla", dt0=0.02, adaptive=True,
                           rtol=1e-3, atol=1e-5, seed=7, event=barrier,
                           saveat=jnp.linspace(0.1, 1.0, 10))
res_dbl = solve_ensemble_local(hit_ens, alg="em", ensemble="kernel",
                               backend="xla", dt0=0.02, adaptive=True,
                               rtol=1e-3, atol=1e-5, seed=7, event=barrier,
                               error_est="doubling",
                               saveat=jnp.linspace(0.1, 1.0, 10))
hit = res.t_final < 1.0
t_hit = jnp.where(hit, res.t_final, 0).sum() / jnp.maximum(hit.sum(), 1)
print(f"\nadaptive em + barrier event: {int(hit.sum())}/512 paths hit X=0.25,"
      f"\n  mean hitting time {float(t_hit):.3f},"
      f"\n  per-path steps min/max = {int(res.naccept.min())}/{int(res.naccept.max())}"
      f" (per-trajectory adaptive dt), rejects = {int(res.nreject.sum())},"
      f"\n  drift evals: embedded pair {int(res.nf)} vs step doubling "
      f"{int(res_dbl.nf)} ({float(res_dbl.nf) / float(res.nf):.1f}x)")

# --- gradients through the same front door: sensitivity="adjoint" ----------
# Any differentiable loss of the solve supports jax.grad.  Adaptive solves
# need an explicit attempt bound for the reverse pass (the while-loop is not
# reverse-differentiable): probe once with suggest_adjoint_steps, then
# differentiate.  Fixed-dt solves need no bound, and checkpoint_every= keeps
# backward memory O(sqrt(n_steps)) instead of O(n_steps) — see
# benchmarks/bench_gradients.py and docs/architecture.md "Gradients are a
# dispatch capability".
from repro.core.sensitivity import suggest_adjoint_steps

dprob = ODEProblem(lorenz, jnp.asarray([1.0, 0.0, 0.0], jnp.float64),
                   jnp.asarray([10.0, 21.0, 8 / 3], jnp.float64), (0.0, 1.0))
rho64 = jnp.linspace(18.0, 24.0, 32, dtype=jnp.float64)
dps = jnp.stack([jnp.full((32,), 10.0), rho64, jnp.full((32,), 8 / 3)], axis=1)
grad_kw = dict(alg="tsit5", ensemble="kernel", backend="xla", t0=0.0, tf=1.0,
               dt0=1e-2, rtol=1e-6, atol=1e-6)
dens = EnsembleProblem(dprob, 32, ps=dps)
bound = suggest_adjoint_steps(dens, **grad_kw)


def loss(p):
    sub = EnsembleProblem(dprob, 32, ps=p)
    out = solve_ensemble_local(sub, sensitivity="adjoint",
                               adjoint_steps=bound, **grad_kw)
    return jnp.sum(out.u_final ** 2)


g = jax.jit(jax.grad(loss))(dps)
print(f"\nadjoint gradients: dL/drho for 32-member Lorenz sweep "
      f"(attempt bound {bound}),"
      f"\n  g[:3, 1] = {g[:3, 1]}  — same dispatch, jax.grad just works")

# --- data-driven DEs: lookup tables through the same front door ------------
# A forced oscillator whose drive term is MEASURED, not analytic: the force
# curve lives in a UniformTable1D riding `prob.data` (the texture-memory
# analogue, paper §6.7).  XLA strategies close the RHS over the table; the
# Pallas kernel stages it into VMEM once per lane tile and interpolates
# in-register (docs/kernels.md "VMEM-resident dataset tables").  Because
# tables are pytree leaves, jax.grad reaches the MEASUREMENTS themselves —
# calibration of the forcing curve is one grad away.
from repro.configs.de_problems import forced_oscillator_problem

fprob = forced_oscillator_problem()          # data={"force": UniformTable1D}
amps = jnp.linspace(0.5, 1.5, 256, dtype=jnp.float64)
fens = EnsembleProblem(fprob, 256, u0s=jnp.stack([fprob.u0] * 256) *
                       amps[:, None])
fres = solve_ensemble_local(fens, alg="tsit5", ensemble="kernel",
                            backend="pallas", saveat=jnp.linspace(0., 5., 6),
                            dt0=1e-2, rtol=1e-7, atol=1e-7)
print(f"\nforced oscillator from a 65-knot force table "
      f"(kernel/pallas, table in VMEM):\n  u_final[0] = {fres.u_final[0]}")

# --- serving: async submit/poll with continuous batching -------------------
# Production traffic is many small heterogeneous requests, not one blob.
# EnsembleService keeps ONE compiled slot program running: finished lanes
# retire early and are refilled from the queue without recompilation, and
# every served result is bitwise a fresh solve_ensemble_local of that
# request (docs/architecture.md "Serving").
from repro.serve import EnsembleService

svc = EnsembleService(slot_width=8, segment_steps=64)
svc.start()                                  # pump loop on a background thread
sigma, beta = 10.0, 8.0 / 3.0
sprob = ODEProblem(lorenz, jnp.asarray([1.0, 0.0, 0.0]),
                   jnp.asarray([sigma, 21.0, beta]), (0.0, 2.0))
tickets = []
for tf in (0.5, 1.0, 2.0):                   # three tenants, three horizons
    rhos = jnp.linspace(19.0, 24.0, 4)
    sps = jnp.stack([jnp.full((4,), sigma), rhos, jnp.full((4,), beta)], 1)
    tickets.append(svc.submit(EnsembleProblem(sprob, 4, ps=sps), alg="tsit5",
                              tf=tf, dt0=1e-2, tenant=f"tenant-{tf}"))
for tk in tickets:
    tk.wait(timeout=120.0)                   # or poll tk.done, non-blocking
svc.stop()
print("\nserved 3 async requests through one continuously-batched program:")
for tk, tf in zip(tickets, (0.5, 1.0, 2.0)):
    print(f"  tf={tf}: status={tk.result.status} nf={tk.result.nf} "
          f"latency={tk.latency:.3f}s")
print(f"  per-tenant accounting: "
      f"{ {t: a['nf'] for t, a in svc.accounting.items()} }")
