"""SDE ensembles (paper §6.8): Black-Scholes asset paths (GBM) via the
kernel-fused Euler-Maruyama and weak-order-2 Platen solvers; Monte-Carlo
option pricing against the closed form.

    PYTHONPATH=src python examples/sde_finance.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem
from repro.core.sde import solve_sde_ensemble
from repro.configs.de_problems import gbm_problem

R, V, X0, T = 0.05, 0.4, 1.0, 1.0
N, n_steps = 50_000, 250

prob = gbm_problem(r=R, v=V, dtype=jnp.float32)
prob = type(prob)(prob.f, prob.g, jnp.full((3,), X0, jnp.float32),
                  jnp.asarray([R, V], jnp.float32), (0.0, T),
                  noise="diagonal", name="gbm")
ens = EnsembleProblem(prob, N)
res = solve_sde_ensemble(ens, jax.random.PRNGKey(0), T / n_steps, n_steps,
                         method="platen_w2", ensemble="kernel",
                         save_every=n_steps)
X = np.asarray(res.u_final)[:, 0].astype(np.float64)

mean_exact = X0 * np.exp(R * T)
print(f"E[X_T]   MC = {X.mean():.5f}   analytic = {mean_exact:.5f}   "
      f"rel err = {abs(X.mean() - mean_exact) / mean_exact:.2e}")

# European call, strike K: Black-Scholes closed form vs MC
K = 1.1
from math import erf, exp, log, sqrt
def Phi(x):
    return 0.5 * (1 + erf(x / sqrt(2)))
d1 = (log(X0 / K) + (R + V * V / 2) * T) / (V * sqrt(T))
d2 = d1 - V * sqrt(T)
bs = X0 * Phi(d1) - K * exp(-R * T) * Phi(d2)
mc = float(np.mean(np.maximum(X - K, 0.0)) * np.exp(-R * T))
se = float(np.std(np.maximum(X - K, 0.0)) / np.sqrt(N))
print(f"call(K={K}) MC = {mc:.5f} ± {se:.5f}   Black-Scholes = {bs:.5f}")
assert abs(mc - bs) < 4 * se + 2e-3
print(f"{N:,} paths × {n_steps} steps, single fused computation — the"
      " paper's §6.8 workflow.")
