"""SDE ensembles (paper §6.8): Black-Scholes asset paths (GBM) via the
kernel-fused Euler-Maruyama and weak-order-2 Platen solvers; Monte-Carlo
option pricing against the closed form — then the same workflow driven by
MARKET DATA: a time-varying short rate r(t) and vol v(t) served from
`UniformTable1D` lookups through the `prob.data` slot (the texture-memory
analogue, §6.7), so the fused kernel prices against a term structure
without leaving the device.

    PYTHONPATH=src python examples/sde_finance.py
"""
from math import erf, exp, log, sqrt

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem, UniformTable1D, interp1d
from repro.core.ensemble import solve_ensemble_local
from repro.core.sde import solve_sde_ensemble
from repro.configs.de_problems import gbm_problem

R, V, X0, T = 0.05, 0.4, 1.0, 1.0
N, n_steps = 50_000, 250


def Phi(x):
    return 0.5 * (1 + erf(x / sqrt(2)))


def constant_coefficient_pricing():
    """Flat-parameter GBM: Monte-Carlo vs the Black-Scholes closed form."""
    prob = gbm_problem(r=R, v=V, dtype=jnp.float32)
    prob = type(prob)(prob.f, prob.g, jnp.full((3,), X0, jnp.float32),
                      jnp.asarray([R, V], jnp.float32), (0.0, T),
                      noise="diagonal", name="gbm")
    ens = EnsembleProblem(prob, N)
    res = solve_sde_ensemble(ens, jax.random.PRNGKey(0), T / n_steps, n_steps,
                             method="platen_w2", ensemble="kernel",
                             save_every=n_steps)
    X = np.asarray(res.u_final)[:, 0].astype(np.float64)

    mean_exact = X0 * np.exp(R * T)
    print(f"E[X_T]   MC = {X.mean():.5f}   analytic = {mean_exact:.5f}   "
          f"rel err = {abs(X.mean() - mean_exact) / mean_exact:.2e}")

    # European call, strike K: Black-Scholes closed form vs MC
    K = 1.1
    d1 = (log(X0 / K) + (R + V * V / 2) * T) / (V * sqrt(T))
    d2 = d1 - V * sqrt(T)
    bs = X0 * Phi(d1) - K * exp(-R * T) * Phi(d2)
    mc = float(np.mean(np.maximum(X - K, 0.0)) * np.exp(-R * T))
    se = float(np.std(np.maximum(X - K, 0.0)) / np.sqrt(N))
    print(f"call(K={K}) MC = {mc:.5f} ± {se:.5f}   Black-Scholes = {bs:.5f}")
    assert abs(mc - bs) < 4 * se + 2e-3


def market_data_pricing():
    """GBM under a TERM STRUCTURE: r(t) and v(t) are lookup tables (think:
    bootstrapped yield curve, implied-vol term structure).  The tables ride
    `SDEProblem.data` into the fused kernel — broadcast once into VMEM per
    lane tile — and the drift/diffusion interpolate them per step.

    With time-varying deterministic coefficients, X_T is still lognormal:
        E[X_T] = X0 * exp(∫ r dt),
    and a European call prices by Black-Scholes with r̄ = mean(r),
    v̄ = sqrt(mean(v²)) — exact integrals of the piecewise-linear curves
    give the reference.
    """
    K_tab = 33
    tk = np.linspace(0.0, T, K_tab)
    r_curve = 0.03 + 0.04 * tk / T                 # upward-sloping rates
    v_curve = 0.45 - 0.15 * tk / T                 # decaying vol term struct.
    dxk = float(tk[1] - tk[0])
    data = {"r": UniformTable1D(jnp.asarray(r_curve, jnp.float32), 0.0, dxk),
            "v": UniformTable1D(jnp.asarray(v_curve, jnp.float32), 0.0, dxk)}

    def drift(u, p, t, d):
        return interp1d(d["r"], t) * u

    def diffusion(u, p, t, d):
        return interp1d(d["v"], t) * u

    base = gbm_problem(dtype=jnp.float32)
    prob = type(base)(drift, diffusion, jnp.full((1,), X0, jnp.float32),
                      jnp.zeros(1, jnp.float32), (0.0, T),
                      noise="diagonal", data=data, name="gbm_market")
    ens = EnsembleProblem(prob, N)
    res = solve_ensemble_local(ens, alg="em", ensemble="kernel",
                               backend="pallas", dt0=T / n_steps,
                               n_steps=n_steps, save_every=n_steps, seed=0)
    X = np.asarray(res.u_final)[:, 0].astype(np.float64)

    # exact integrals of the piecewise-linear curves (trapezoid is exact)
    r_bar = float(np.trapezoid(r_curve, tk) / T)
    v2_bar = float(np.trapezoid(v_curve ** 2, tk) / T)
    mean_exact = X0 * exp(r_bar * T)
    print(f"E[X_T]   MC = {X.mean():.5f}   term-structure analytic = "
          f"{mean_exact:.5f}   rel err = "
          f"{abs(X.mean() - mean_exact) / mean_exact:.2e}")

    K = 1.05
    vb = sqrt(v2_bar)
    d1 = (log(X0 / K) + (r_bar + v2_bar / 2) * T) / (vb * sqrt(T))
    d2 = d1 - vb * sqrt(T)
    bs = X0 * Phi(d1) - K * exp(-r_bar * T) * Phi(d2)
    mc = float(np.mean(np.maximum(X - K, 0.0)) * np.exp(-r_bar * T))
    se = float(np.std(np.maximum(X - K, 0.0)) / np.sqrt(N))
    print(f"call(K={K}) MC = {mc:.5f} ± {se:.5f}   "
          f"Black-Scholes(r̄,v̄) = {bs:.5f}")
    # EM at dt=T/250 on a drifting-coefficient GBM: allow discretization bias
    assert abs(mc - bs) < 4 * se + 4e-3

    print(f"{N:,} paths × {n_steps} steps against a {K_tab}-knot term "
          "structure, tables resident in the fused kernel — §6.7 + §6.8.")


if __name__ == "__main__":
    constant_coefficient_pricing()
    market_data_pricing()
