"""GPU-parallel parameter estimation with AD through the solver (paper §6.6,
the SciMLSensitivity minibatching tutorial): recover Lorenz's rho from
trajectory data by gradient descent, gradients vmapped over an ensemble of
candidate fits (population fitting / minibatching across the ensemble axis).

    PYTHONPATH=src python examples/parameter_estimation.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import get_tableau
from repro.core.sensitivity import grad_discrete_adjoint, solve_fixed_remat
from repro.configs.de_problems import lorenz_problem

TAB = get_tableau("tsit5")
prob = lorenz_problem(jnp.float64)
dt, n_steps, save_every = 0.005, 200, 20
TRUE_RHO = 17.3

# synth data with the true parameter
p_true = jnp.asarray([10.0, TRUE_RHO, 8 / 3])
data, _ = solve_fixed_remat(prob.f, TAB, prob.u0, p_true, 0.0, dt, n_steps,
                            save_every)


def loss_of_us(us):
    return jnp.mean((us - data) ** 2)


def fit(rho0, iters=60, lr=0.15):
    p = jnp.asarray([10.0, rho0, 8 / 3])
    for _ in range(iters):
        val, (_, g_p) = grad_discrete_adjoint(
            loss_of_us, prob.f, TAB, prob.u0, p, 0.0, dt, n_steps, save_every)
        p = p.at[1].add(-lr * g_p[1])      # estimate rho only
    return float(p[1]), float(val)


# a small population of initial guesses, fitted in parallel (vmap over fits
# would be the full GPU pattern; loop here keeps the example readable)
guesses = [8.0, 14.0, 22.0, 28.0]
print(f"true rho = {TRUE_RHO}")
for g in guesses:
    rho, final_loss = fit(g)
    print(f"  init {g:5.1f} -> fitted {rho:7.4f}   loss {final_loss:.3e}")
    assert abs(rho - TRUE_RHO) < 0.2, "fit failed to converge"
print("adjoint-through-the-solver gradients recover the parameter from every"
      " basin (paper §6.6).")
