"""GPU-parallel parameter estimation with AD through the front door (paper
§6.6, the SciMLSensitivity minibatching tutorial): recover Lorenz's rho from
trajectory data by gradient descent.

The whole candidate POPULATION rides the ensemble axis: each initial guess is
one trajectory of a `solve_ensemble_local` call with ``sensitivity="adjoint"``,
so ONE `jax.grad` reverse pass per descent iteration computes every member's
gradient — the checkpointed discrete adjoint keeps the backward memory at
O(sqrt-steps) regardless of how long the fit window is.  Trajectories are
independent, so the gradient of the summed loss IS the per-member gradient.

    PYTHONPATH=src python examples/parameter_estimation.py
"""
import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp

from repro.core import EnsembleProblem
from repro.core.ensemble import solve_ensemble_local
from repro.core.sensitivity import suggest_adjoint_steps
from repro.configs.de_problems import lorenz_problem

TRUE_RHO = 17.3
SAVEAT = jnp.linspace(0.1, 1.0, 10)
SOLVE_KW = dict(alg="tsit5", ensemble="kernel", backend="xla", t0=0.0, tf=1.0,
                dt0=1e-2, rtol=1e-7, atol=1e-7, saveat=SAVEAT)

prob = lorenz_problem(jnp.float64)


def population(rhos):
    """One ensemble lane per candidate rho (sigma/beta held at truth)."""
    rhos = jnp.asarray(rhos, jnp.float64)
    P = rhos.shape[0]
    ps = jnp.stack([jnp.full((P,), 10.0), rhos, jnp.full((P,), 8 / 3)],
                   axis=1)
    u0s = jnp.tile(prob.u0[None], (P, 1))
    return EnsembleProblem(prob, P, u0s=u0s, ps=ps)


def make_data():
    """Synthetic observations: the true-parameter trajectory on SAVEAT."""
    return solve_ensemble_local(population([TRUE_RHO]), **SOLVE_KW).us[0]


def fit(rho0s, data, iters=60, lr=0.15, adjoint_steps=None):
    """Descend every initial guess in parallel; returns (rhos, final_loss)."""
    rho0s = jnp.asarray(rho0s, jnp.float64)
    u0s = jnp.tile(prob.u0[None], (rho0s.shape[0], 1))
    if adjoint_steps is None:
        adjoint_steps = suggest_adjoint_steps(population(rho0s), margin=1.0,
                                              **SOLVE_KW)

    def total_loss(ps):
        ep = EnsembleProblem(prob, ps.shape[0], u0s=u0s, ps=ps)
        res = solve_ensemble_local(ep, sensitivity="adjoint",
                                   adjoint_steps=adjoint_steps, **SOLVE_KW)
        return jnp.sum(jnp.mean((res.us - data[None]) ** 2, axis=(1, 2)))

    step = jax.jit(jax.value_and_grad(total_loss))
    ps = jnp.stack([jnp.full_like(rho0s, 10.0), rho0s,
                    jnp.full_like(rho0s, 8 / 3)], axis=1)
    val = jnp.inf
    for _ in range(iters):
        val, g = step(ps)
        ps = ps.at[:, 1].add(-lr * g[:, 1])    # estimate rho only
    return ps[:, 1], float(val)


def main():
    data = make_data()
    guesses = jnp.asarray([8.0, 14.0, 22.0, 28.0])
    rhos, final_loss = fit(guesses, data)
    print(f"true rho = {TRUE_RHO}   (population fitted in one adjoint "
          f"reverse pass per iteration)")
    for g, r in zip(guesses, rhos):
        print(f"  init {float(g):5.1f} -> fitted {float(r):7.4f}")
        assert abs(float(r) - TRUE_RHO) < 0.2, "fit failed to converge"
    print(f"final population loss {final_loss:.3e}: adjoint-through-the-"
          "solver gradients recover the parameter from every basin (§6.6).")


if __name__ == "__main__":
    main()
