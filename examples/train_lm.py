"""End-to-end LM training driver on the full substrate: data pipeline ->
trainer (accum, AdamW, cosine) -> async checkpointing -> restart.

Default preset trains a ~13M-param internlm2-family model for 120 steps on
CPU (minutes); --arch selects any zoo member (reduced with --smoke) and the
same script is the TPU entry point via launch/train.py.

    PYTHONPATH=src python examples/train_lm.py --steps 120
    PYTHONPATH=src python examples/train_lm.py --resume   # restart demo
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.archs import get_arch
from repro.data.pipeline import DataPipeline
from repro.dist.fault import TrainSupervisor
from repro.models.model import build_model
from repro.optim.adamw import AdamW, cosine_schedule
from repro.train.trainer import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b-smoke")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    # widen the smoke config to ~13M params for a real-ish loss curve
    if args.arch.endswith("-smoke"):
        cfg = dataclasses.replace(cfg, d_model=256, d_ff=1024, n_layers=6,
                                  vocab_size=4096)
    model = build_model(cfg, dtype=jnp.float32)
    opt = AdamW(lr=cosine_schedule(args.lr, 20, args.steps),
                weight_decay=0.01)
    plan = make_train_step(model, opt, mesh=None, accum=args.accum,
                           donate=False)

    sup = TrainSupervisor(args.ckpt_dir, save_every=args.save_every)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    state = {"params": params, "opt": opt_state}
    start_step, state, extra = (sup.resume_or_init(lambda: state, state)
                                if args.resume else (0, state, {}))
    params, opt_state = state["params"], state["opt"]
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name}  params={n_params/1e6:.1f}M  "
          f"start_step={start_step}")

    pipe = DataPipeline(cfg, batch=args.batch, seq_len=args.seq,
                        start_step=extra.get("cursor", 0))
    t0 = time.perf_counter()
    for step in range(start_step + 1, args.steps + 1):
        batch = next(pipe)
        params, opt_state, m = plan.step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == 1:
            tok_s = args.batch * args.seq * 10 / max(
                time.perf_counter() - t0, 1e-9)
            t0 = time.perf_counter()
            print(f"step {step:4d}  loss {float(m['loss']):.4f}  "
                  f"lr {float(m['lr']):.2e}  "
                  f"gnorm {float(m['grad_norm']):.2f}  ~{tok_s:,.0f} tok/s")
        sup.maybe_save(step, {"params": params, "opt": opt_state},
                       {"cursor": pipe.cursor()})
    pipe.close()
    print("done. checkpoints in", args.ckpt_dir,
          "(rerun with --resume to continue).")


if __name__ == "__main__":
    main()
