"""Event handling (paper §6.6 / Fig. 8): an ensemble of bouncing balls with
per-trajectory coefficients of restitution, solved in the fused lanes path
with per-lane event detection + interpolated root-finding.

    PYTHONPATH=src python examples/bouncing_ball.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import AdaptiveOptions, get_tableau, solve_adaptive
from repro.configs.de_problems import (bouncing_ball_event,
                                       bouncing_ball_problem)

B = 8
# restitution sweep; kept >= 0.75 so the Zeno accumulation point (total
# bounce time t1*(1+2e/(1-e))) stays beyond tf — classical bouncing-ball
# caveat, same as the paper's demo regime
es = jnp.linspace(0.75, 0.95, B, dtype=jnp.float64)
ps = jnp.stack([jnp.full((B,), 9.8), es])               # (2, B)
u0 = jnp.stack([jnp.full((B,), 10.0), jnp.zeros(B)])    # x=10, v=0

prob = bouncing_ball_problem()
ev = bouncing_ball_event()
saveat = jnp.linspace(0.0, 8.0, 81)
res, evlog = solve_adaptive(prob.f, get_tableau("tsit5"), u0, ps, 0.0, 8.0,
                            1e-3, saveat=saveat,
                            opts=AdaptiveOptions(rtol=1e-9, atol=1e-9,
                                                 max_iters=200_000),
                            event=ev, lanes=True)

t1 = float(np.sqrt(2 * 10 / 9.8))
print(f"first impact (analytic): t = {t1:.4f}s  — all lanes share it")
print(f"events per lane: {np.asarray(evlog['event_count'])}")
print("\n  t      " + "  ".join(f"e={float(e):.2f}" for e in es))
xs = np.asarray(res.us)[:, 0, :]   # (S, B) heights
for i in range(0, len(saveat), 8):
    bar = "  ".join(f"{xs[i, j]:6.2f}" for j in range(B))
    print(f"{float(saveat[i]):5.2f}  {bar}")
print("\nHigher restitution => more bounces survive (paper Fig. 8 dynamics);"
      "\nheights never go negative — events clamp at the surface.")
assert float(xs.min()) > -1e-3
