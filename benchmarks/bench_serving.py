"""Continuous-batching serving throughput (the tentpole claim of the
serving subsystem, docs/architecture.md "Serving").

Workload: a burst of small heterogeneous ensemble requests (4 lanes each,
mixed time spans / step counts) arriving at t=0.  Two ways to serve it:

  * serial   — one-batch-at-a-time: each request is its own
    `solve_ensemble_local(..., ensemble="kernel", backend="xla")` dispatch,
    run to completion before the next starts (the pre-PR9 front-door shape).
  * serving  — `EnsembleService`: all requests share one compiled slot pool;
    finished lanes retire early and are refilled from the queue without
    recompilation, so the device runs at full lane width the whole time.

Reported per section: problems/sec for both paths, the throughput speedup
(bar: >= 1.5x), and request-latency p50/p99 (serial latency for request i is
the cumulative completion time — everything arrived at t=0).  Compilation is
excluded from both paths (untimed warmup per distinct signature); the serving
path's additional no-recompile advantage under signature churn is therefore
NOT counted — the measured speedup is pure occupancy.

Writes results/BENCH_serving.json (sections: ode, sde, summary).
"""
from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

SPEEDUP_BAR = 1.5


def _percentiles(lat):
    lat = np.asarray(sorted(lat))
    return float(np.percentile(lat, 50)), float(np.percentile(lat, 99))


def _serial_solve(reqs, solve_one):
    """One-batch-at-a-time baseline: returns (total_s, latencies)."""
    lat, t_start = [], time.perf_counter()
    for req in reqs:
        solve_one(req)
        lat.append(time.perf_counter() - t_start)  # arrived at t=0
    return time.perf_counter() - t_start, lat


def _served_solve(svc, reqs, submit_one):
    tickets = [submit_one(svc, r) for r in reqs]
    t0 = time.perf_counter()
    svc.drain()
    total = time.perf_counter() - t0
    return total, [t.latency for t in tickets]


def _section(name, n_req, t_serial, lat_serial, t_serve, lat_serve):
    p50s, p99s = _percentiles(lat_serial)
    p50v, p99v = _percentiles(lat_serve)
    speedup = t_serial / t_serve
    rec = dict(
        n_requests=n_req,
        serial=dict(total_s=t_serial, problems_per_s=n_req / t_serial,
                    p50_s=p50s, p99_s=p99s),
        serving=dict(total_s=t_serve, problems_per_s=n_req / t_serve,
                     p50_s=p50v, p99_s=p99v),
        speedup=speedup, bar=SPEEDUP_BAR, meets_bar=bool(speedup >= SPEEDUP_BAR),
    )
    from .common import row
    print(row(f"serving/{name}/serial", t_serial / n_req,
              f"{n_req / t_serial:.1f} problems_per_s"))
    print(row(f"serving/{name}/continuous", t_serve / n_req,
              f"{n_req / t_serve:.1f} problems_per_s "
              f"speedup={speedup:.2f}x p50={p50v * 1e3:.1f}ms "
              f"p99={p99v * 1e3:.1f}ms"))
    return rec


def _ode_section():
    from repro.configs.de_problems import lorenz_ensemble
    from repro.core import EnsembleProblem, solve_ensemble_local
    from repro.serve import EnsembleService

    TFS = (0.5, 1.0, 2.0)
    N_REQ = 18
    ep = lorenz_ensemble(4 * N_REQ, dtype=jnp.float32)
    u0s, ps = (np.asarray(a) for a in ep.materialize())
    reqs = [(EnsembleProblem(ep.prob, 4, u0s=u0s[4 * i:4 * i + 4],
                             ps=ps[4 * i:4 * i + 4]), TFS[i % len(TFS)])
            for i in range(N_REQ)]

    def solve_one(req):
        sub, tf = req
        r = solve_ensemble_local(sub, alg="tsit5", ensemble="kernel",
                                 backend="xla", t0=0.0, tf=tf, dt0=1e-2,
                                 rtol=1e-6, atol=1e-6, lane_tile=4)
        np.asarray(r.u_final)  # block

    def submit_one(svc, req):
        sub, tf = req
        return svc.submit(sub, alg="tsit5", tf=tf, dt0=1e-2)

    # warmup: compile each distinct serial signature + the slot program
    for tf in TFS:
        solve_one((reqs[0][0], tf))
    wsvc = EnsembleService(slot_width=16, segment_steps=64)
    submit_one(wsvc, reqs[0])
    wsvc.drain()

    t_serial, lat_serial = _serial_solve(reqs, solve_one)
    svc = EnsembleService(slot_width=16, segment_steps=64,
                          max_pending=2 * N_REQ)
    t_serve, lat_serve = _served_solve(svc, reqs, submit_one)
    return _section("ode_tsit5", N_REQ, t_serial, lat_serial,
                    t_serve, lat_serve)


def _sde_section():
    from repro.configs.de_problems import gbm_problem
    from repro.core import EnsembleProblem, solve_ensemble_local
    from repro.serve import EnsembleService

    NSTEPS = (512, 1024, 2048)
    N_REQ = 18
    SEED = 0
    prob = gbm_problem(dtype=jnp.float32)
    u0 = np.full((4, 3), 1.0, np.float32)
    p = np.tile(np.asarray([1.5, 0.1], np.float32), (4, 1))
    reqs = [(EnsembleProblem(prob, 4, u0s=u0, ps=p), NSTEPS[i % len(NSTEPS)],
             4 * i) for i in range(N_REQ)]

    def solve_one(req):
        sub, n_steps, off = req
        r = solve_ensemble_local(sub, alg="em", ensemble="kernel",
                                 backend="xla", t0=0.0, tf=n_steps * 1e-3,
                                 dt0=1e-3, n_steps=n_steps,
                                 save_every=n_steps, seed=SEED,
                                 lane_offset=off)
        np.asarray(r.u_final)  # block

    def submit_one(svc, req):
        sub, n_steps, _ = req
        return svc.submit(sub, alg="em", t0=0.0, tf=n_steps * 1e-3,
                          dt0=1e-3, n_steps=n_steps)

    for n_steps in NSTEPS:
        solve_one((reqs[0][0], n_steps, 0))
    wsvc = EnsembleService(seed=SEED, slot_width=16, segment_steps=256)
    submit_one(wsvc, reqs[0])
    wsvc.drain()

    t_serial, lat_serial = _serial_solve(reqs, solve_one)
    svc = EnsembleService(seed=SEED, slot_width=16, segment_steps=256,
                          max_pending=2 * N_REQ)
    t_serve, lat_serve = _served_solve(svc, reqs, submit_one)
    return _section("sde_em", N_REQ, t_serial, lat_serial, t_serve, lat_serve)


def _stiff_section():
    """Non-resumable leg: rosenbrock requests coalesce into ONE BatchPool
    solve per pump (lazy-W refresh gates are batch-reduced — lanes cannot
    retire early), so the serving win here is pure batch amortization."""
    from repro.configs.de_problems import rober_problem
    from repro.core import EnsembleProblem, solve_ensemble_local
    from repro.serve import EnsembleService

    N_REQ = 8
    rp = rober_problem(dtype=jnp.float64)
    u0 = np.tile(np.asarray([1.0, 0.0, 0.0]), (4, 1))
    p = np.tile(np.asarray([0.04, 3e7, 1e4]), (4, 1))
    reqs = [EnsembleProblem(rp, 4, u0s=u0, ps=p) for _ in range(N_REQ)]
    kw = dict(t0=0.0, tf=1.0, dt0=1e-6, rtol=1e-5, atol=1e-8)

    def solve_one(sub):
        r = solve_ensemble_local(sub, alg="rosenbrock23", ensemble="kernel",
                                 backend="xla", **kw)
        np.asarray(r.u_final)  # block

    def submit_one(svc, sub):
        return svc.submit(sub, alg="rosenbrock23", **kw)

    solve_one(reqs[0])                       # serial signature compile
    wsvc = EnsembleService(max_pending=2 * N_REQ)
    for sub in reqs:                         # coalesced-width compile
        submit_one(wsvc, sub)
    wsvc.drain()

    t_serial, lat_serial = _serial_solve(reqs, solve_one)
    svc = EnsembleService(max_pending=2 * N_REQ)
    t_serve, lat_serve = _served_solve(svc, reqs, submit_one)
    return _section("stiff_rosenbrock23", N_REQ, t_serial, lat_serial,
                    t_serve, lat_serve)


def main() -> None:
    from .common import HEADER, update_results_json
    print(HEADER)
    ode = _ode_section()
    sde = _sde_section()
    stiff = _stiff_section()
    summary = dict(
        speedup_bar=SPEEDUP_BAR,
        meets_bar=bool(ode["meets_bar"] and sde["meets_bar"]
                       and stiff["meets_bar"]),
        note="occupancy-only speedup; no-recompile advantage not counted",
    )
    path = "results/BENCH_serving.json"
    update_results_json(path, "ode", ode)
    update_results_json(path, "sde", sde)
    update_results_json(path, "stiff", stiff)
    update_results_json(path, "summary", summary)


if __name__ == "__main__":
    main()
