"""§6.7: dataset interpolation inside the RHS (texture-memory analogue).

Wind-drag bouncing-ball RHS with a 1-D lookup table: gather path vs one-hot
MXU path vs a no-table control, integrated by the fused kernel ensemble.
The paper reports 2x vs CPU-interpolation; our structural analogue reports
the overhead of in-RHS interpolation per mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import EnsembleProblem, ODEProblem
from repro.core.ensemble import solve_ensemble_local
from repro.core.interp import UniformTable1D, interp1d

from .common import HEADER, bench, row

N = 1024


def make_prob(mode):
    wind = UniformTable1D(0.1 * jnp.sin(0.25 * jnp.arange(64,
                                                          dtype=jnp.float32)),
                          0.0, 0.25)

    def rhs(u, p, t):
        if mode == "none":
            drag = 0.0
        else:
            drag = interp1d(wind, u[0], mode)
        return jnp.stack([u[1], -9.8 - drag * u[1]])

    return ODEProblem(rhs, jnp.asarray([10.0, 0.0], jnp.float32),
                      jnp.zeros(1, jnp.float32), (0.0, 1.0),
                      name=f"drag_{mode}")


def main() -> None:
    print(HEADER)
    base = None
    for mode in ("none", "gather", "onehot"):
        prob = make_prob(mode)
        ep = EnsembleProblem(prob, N)

        def run():
            return solve_ensemble_local(ep, ensemble="kernel",
                                        adaptive=False, dt0=1e-3, t0=0.0,
                                        tf=1.0, save_every=1000).u_final

        t = bench(jax.jit(run))
        if mode == "none":
            base = t
        print(row(f"texture/{mode}", t, f"{t / base:.2f}x_vs_no_table"))


if __name__ == "__main__":
    main()
