"""§6.7: dataset interpolation inside the RHS (texture-memory analogue).

A forced oscillator whose drive term comes from a 1-D lookup table — the
paper's data-driven-DE workload.  Two implementation extremes:

  * ``callback``: the table lookup leaves the accelerator — a
    ``jax.pure_callback`` into ``np.interp`` on the host, inside a vmap'd
    fixed-dt solve.  This is the "interpolate in Python" strategy the paper's
    texture-memory section argues against: every RHS evaluation round-trips
    through the host.
  * fused kernel (``gather`` / ``onehot`` / ``cubic`` modes): the table rides
    the `prob.data` slot into the fused ensemble kernel — broadcast into
    VMEM once per lane tile (see docs/kernels.md), interpolated in-register.

Writes results/BENCH_texture_interp.json.  All numbers are single-core CPU
(interpret-mode Pallas): they measure the *structural* cost of leaving the
device per step vs keeping the dataset resident, not TPU texture hardware.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem, ODEProblem, UniformTable1D
from repro.core.ensemble import solve_ensemble_local
from repro.core.interp import interp1d

from .common import HEADER, bench, row

N = 1024
N_STEPS = 200
DT = 1.0 / N_STEPS
K = 64


def _table(dtype):
    xs = np.linspace(0.0, 1.0, K)
    F = np.sin(6.0 * xs) + 0.5 * np.cos(17.0 * xs)
    return UniformTable1D(jnp.asarray(F, dtype), 0.0, float(xs[1] - xs[0]))


def _ensemble(prob):
    u0s = jnp.stack([jnp.asarray([1.0, 0.0], prob.u0.dtype)] * N)
    u0s = u0s * jnp.linspace(0.5, 1.5, N, dtype=prob.u0.dtype)[:, None]
    ps = jnp.stack([jnp.asarray([4.0, 0.2], prob.p.dtype)] * N)
    return EnsembleProblem(prob, N, u0s=u0s, ps=ps)


def make_table_prob(mode, dtype=jnp.float32):
    tab = _table(dtype)

    def rhs(u, p, t, data):
        force = interp1d(data["force"], t, mode)
        return jnp.stack([u[1], -p[0] * u[0] - p[1] * u[1] + force])

    return ODEProblem(rhs, jnp.asarray([1.0, 0.0], dtype),
                      jnp.asarray([4.0, 0.2], dtype), (0.0, 1.0),
                      data={"force": tab}, name=f"forced_osc_{mode}")


def make_callback_prob(dtype=jnp.float32):
    """Host-interpolation baseline: np.interp behind jax.pure_callback."""
    xs = np.linspace(0.0, 1.0, K)
    F = (np.sin(6.0 * xs) + 0.5 * np.cos(17.0 * xs)).astype(np.float32)

    def host_interp(t):
        return np.interp(np.asarray(t), xs, F).astype(np.asarray(t).dtype)

    def rhs(u, p, t):
        force = jax.pure_callback(
            host_interp, jax.ShapeDtypeStruct(jnp.shape(t), dtype), t,
            vmap_method="expand_dims")
        return jnp.stack([u[1], -p[0] * u[0] - p[1] * u[1] + force])

    return ODEProblem(rhs, jnp.asarray([1.0, 0.0], dtype),
                      jnp.asarray([4.0, 0.2], dtype), (0.0, 1.0),
                      name="forced_osc_callback")


def main() -> None:
    print(HEADER)
    records = {}

    # host-callback baseline: vmap strategy (a pure_callback cannot live
    # inside the fused Pallas kernel at all — that asymmetry is the point)
    ep = _ensemble(make_callback_prob())

    def run_cb():
        return solve_ensemble_local(ep, alg="tsit5", ensemble="vmap",
                                    adaptive=False, dt0=DT, n_steps=N_STEPS,
                                    save_every=N_STEPS).u_final

    t_cb = bench(jax.jit(run_cb))
    print(row("texture/callback_vmap", t_cb, "host_np.interp_baseline"))
    records["callback_vmap"] = {"seconds": t_cb}

    # fused kernel, table resident in VMEM, one row per interpolation mode
    for mode in ("gather", "onehot", "cubic"):
        epk = _ensemble(make_table_prob(mode))

        def run_kernel(ep_=epk):
            return solve_ensemble_local(ep_, alg="tsit5", ensemble="kernel",
                                        backend="pallas", adaptive=False,
                                        dt0=DT, n_steps=N_STEPS,
                                        save_every=N_STEPS).u_final

        t = bench(jax.jit(run_kernel))
        print(row(f"texture/kernel_{mode}", t,
                  f"{t_cb / t:.1f}x_vs_callback"))
        records[f"kernel_{mode}"] = {"seconds": t,
                                     "speedup_vs_callback": t_cb / t}

    os.makedirs("results", exist_ok=True)
    out = os.path.join("results", "BENCH_texture_interp.json")
    with open(out, "w") as fp:
        json.dump({"N": N, "n_steps": N_STEPS, "table_K": K,
                   "problem": "forced_oscillator", "records": records},
                  fp, indent=2, sort_keys=True)
    print(f"# wrote {out}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
