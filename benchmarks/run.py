"""Benchmark driver: one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only fig4,...]``
Prints ``name,us_per_call,derived`` CSV rows per module, then the roofline
summary table from the dry-run records (if present).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import traceback

# allow both `python -m benchmarks.run` and `python benchmarks/run.py`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

# a module may carry several pipe-separated tags ("fig4|crossover"):
# --only matches any of them, so `--only crossover` selects the pair of
# benches that write results/BENCH_crossover.json
MODULES = [
    ("fig4|crossover", "benchmarks.bench_fig4_crossover"),
    ("table1", "benchmarks.bench_table1_speedups"),
    ("fig56|crossover", "benchmarks.bench_fig56_vs_vmap"),
    ("fig7", "benchmarks.bench_fig7_backends"),
    ("fig9", "benchmarks.bench_fig9_gbm"),
    ("adaptive_sde", "benchmarks.bench_adaptive_sde"),
    ("stiff", "benchmarks.bench_stiff"),
    ("gradients", "benchmarks.bench_gradients"),
    ("fig11", "benchmarks.bench_fig11_crn"),
    ("texture", "benchmarks.bench_texture_interp"),
    ("serving", "benchmarks.bench_serving"),
    ("elastic", "benchmarks.bench_elastic"),
]


def check_bench_imports(modname: str) -> None:
    """Bitrot guard for `--dry`: bench modules import their shared helpers
    lazily inside main() (so a dry import stays cheap), which means a plain
    import check never executes `from .common import bench, row` — rename a
    helper in common.py and every benchmark breaks only at timing time.
    Statically walk the module's AST and verify every name imported from
    within the benchmarks package actually exists."""
    import ast
    import importlib
    import inspect

    mod = importlib.import_module(modname)
    tree = ast.parse(inspect.getsource(mod))
    for node in ast.walk(tree):
        if not isinstance(node, ast.ImportFrom):
            continue
        if node.level:                       # from .common import ...
            target = "benchmarks" + ("." + node.module if node.module else "")
        elif node.module and node.module.startswith("benchmarks"):
            target = node.module
        else:
            continue
        tmod = importlib.import_module(target)
        for alias in node.names:
            if alias.name != "*" and not hasattr(tmod, alias.name):
                raise AssertionError(
                    f"{modname}: `from {target} import {alias.name}` names "
                    "a symbol that no longer exists (signature drift)")


def print_roofline_summary():
    for tag, results_dir in (("baseline", "results"),
                             ("optimized", "results_optimized")):
        path = os.path.join(results_dir, "roofline_all.json")
        if not os.path.exists(path):
            print(f"# (no {path} — run repro.launch.roofline)")
            continue
        with open(path) as f:
            rows = json.load(f)
        print(f"\n# ---- roofline summary [{tag}] "
              "(single-pod; see EXPERIMENTS.md) ----")
        print("arch,shape,bottleneck,t_compute_s,t_memory_s,t_collective_s,"
              "useful_ratio,roofline_fraction")
        for r in rows:
            if "error" in r:
                print(f"{r['arch']},{r['shape']},ERROR,,,,,")
                continue
            print(f"{r['arch']},{r['shape']},{r['bottleneck']},"
                  f"{r['t_compute_s']:.4g},{r['t_memory_s']:.4g},"
                  f"{r['t_collective_s']:.4g},{r['useful_ratio']:.3f},"
                  f"{r['roofline_fraction']:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--dry", action="store_true",
                    help="import every benchmark module and check its entry "
                         "point without timing anything (CI smoke)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    import importlib
    failed = []
    for tag, modname in MODULES:
        if only and not (only & set(tag.split("|"))):
            continue
        if args.dry:
            try:
                mod = importlib.import_module(modname)
                assert callable(getattr(mod, "main")), f"{modname}.main"
                check_bench_imports(modname)
                print(f"# {modname}: ok")
            except Exception as e:  # noqa: BLE001 — report all, then fail
                failed.append(modname)
                print(f"# {modname} FAILED: {type(e).__name__}: {e}")
            continue
        print(f"\n# ==== {modname} ====")
        try:
            importlib.import_module(modname).main()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"# {modname} FAILED: {type(e).__name__}: {e}")
            traceback.print_exc()
    if args.dry:
        sys.exit(1 if failed else 0)
    print_roofline_summary()


if __name__ == "__main__":
    main()
