"""Adaptive vs fixed-dt SDE stepping (this repo's beyond-paper feature).

Measures the cost/benefit of embedded step-doubling control with
virtual-Brownian-tree noise against the paper's fixed-dt kernels on the GBM
ensemble: wall time, RHS-evaluation work (nf), and pathwise strong error
against the closed-form GBM solution driven by the SAME Brownian path.

Writes a machine-readable record to results/BENCH_adaptive_sde.json so CI
and future PRs can diff the numbers.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem, solve_ensemble_local
from repro.configs.de_problems import gbm_problem
from repro.core.sde import default_bridge_depth
from repro.kernels.rng import brownian_bridge_point

from .common import HEADER, bench, row

R, V, N, SEED = 1.5, 0.2, 1024, 7


def _exact_endpoint(depth, dtype):
    n = 3
    lanes = jnp.broadcast_to(jnp.arange(N, dtype=jnp.uint32)[None], (n, N))
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32)[:, None], (n, N))
    WT = brownian_bridge_point(SEED, jnp.full((n, N), 2 ** depth), lanes,
                               rows, depth=depth, t_total=1.0, dtype=dtype)
    return 0.1 * np.exp((R - 0.5 * V * V) + V * np.asarray(WT)).T  # (N, n)


def main() -> None:
    print(HEADER)
    prob = gbm_problem(r=R, v=V, dtype=jnp.float32)
    ep = EnsembleProblem(prob, N)
    records = {}

    def fixed(n_steps):
        return solve_ensemble_local(ep, alg="em", ensemble="kernel",
                                    backend="xla", t0=0.0, tf=1.0,
                                    dt0=1.0 / n_steps, n_steps=n_steps,
                                    save_every=n_steps, seed=SEED)

    def adaptive(rtol):
        return solve_ensemble_local(ep, alg="em", ensemble="kernel",
                                    backend="xla", t0=0.0, tf=1.0, dt0=0.02,
                                    adaptive=True, rtol=rtol, atol=rtol * 1e-2,
                                    seed=SEED)

    for n_steps in (200, 1000):
        f = jax.jit(lambda ns=n_steps: fixed(ns).u_final)
        t = bench(f)
        print(row(f"adaptive_sde/fixed/n={n_steps}", t,
                  f"nf={int(fixed(n_steps).nf)}"))
        records[f"fixed_n{n_steps}"] = {
            "seconds": t, "nf": int(fixed(n_steps).nf)}

    depth = default_bridge_depth(0.0, 1.0, 0.02)
    exact = _exact_endpoint(depth, jnp.float32)
    for rtol in (1e-2, 1e-3, 1e-4):
        f = jax.jit(lambda r=rtol: adaptive(r).u_final)
        t = bench(f)
        res = adaptive(rtol)
        strong = float(np.sqrt(np.mean(
            (np.asarray(res.u_final) - exact) ** 2)))
        print(row(f"adaptive_sde/adaptive/rtol={rtol:g}", t,
                  f"nf={int(res.nf)} strong_rmse={strong:.2e} "
                  f"naccept_mean={float(np.mean(np.asarray(res.naccept))):.0f}"))
        records[f"adaptive_rtol{rtol:g}"] = {
            "seconds": t, "nf": int(res.nf), "strong_rmse": strong,
            "naccept_mean": float(np.mean(np.asarray(res.naccept))),
            "nreject_total": int(np.sum(np.asarray(res.nreject))),
            "brownian_depth": depth}

    os.makedirs("results", exist_ok=True)
    out = os.path.join("results", "BENCH_adaptive_sde.json")
    with open(out, "w") as fp:
        json.dump({"N": N, "problem": "gbm(r=1.5,v=0.2)", "seed": SEED,
                   "records": records}, fp, indent=2, sort_keys=True)
    print(f"# wrote {out}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
