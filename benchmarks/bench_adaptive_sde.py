"""Adaptive vs fixed-dt SDE stepping, and embedded-pair vs step-doubling
error estimation (ISSUE 4 tentpole economics).

Measures, on the GBM ensemble:
  * the paper's fixed-dt kernels (baseline cost);
  * an embedded-vs-doubling WORK-PRECISION sweep: for each estimator, wall
    time, drift-evaluation work (nf) and pathwise strong error against the
    closed-form GBM solution driven by the SAME virtual-Brownian-tree path;
  * the matched-accuracy comparison: for every accuracy step doubling
    reaches, the nf the embedded pair needs for the same strong error
    (log-log interpolation along its work-precision curve) — the ISSUE 4
    acceptance bar is nf_doubling / nf_embedded >= 1.5 somewhere on the
    sweep, i.e. the pair does the same job with >= 1.5x fewer RHS/noise
    evaluations.

Writes a machine-readable record to results/BENCH_adaptive_sde.json so CI
and future PRs can diff the numbers.
"""
from __future__ import annotations

import json
import math
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem, solve_ensemble_local
from repro.configs.de_problems import gbm_problem
from repro.kernels.rng import brownian_bridge_point

from .common import HEADER, bench, row

R, V, N, SEED = 1.5, 0.2, 1024, 7
DEPTH = 14           # deep enough that no sweep point sits on the dyadic floor
RTOLS = (1e-2, 1e-3, 1e-4)


def _exact_endpoint(depth, dtype):
    n = 3
    lanes = jnp.broadcast_to(jnp.arange(N, dtype=jnp.uint32)[None], (n, N))
    rows = jnp.broadcast_to(jnp.arange(n, dtype=jnp.uint32)[:, None], (n, N))
    WT = brownian_bridge_point(SEED, jnp.full((n, N), 2 ** depth), lanes,
                               rows, depth=depth, t_total=1.0, dtype=dtype)
    return 0.1 * np.exp((R - 0.5 * V * V) + V * np.asarray(WT)).T  # (N, n)


def _nf_at(err_target, points):
    """nf needed for err_target, log-log interpolated along (nf, err) points."""
    pts = sorted(points, key=lambda x: x[1])
    for (nf1, e1), (nf0, e0) in zip(pts, pts[1:]):
        if e1 <= err_target <= e0:
            s = ((math.log(nf1) - math.log(nf0))
                 / (math.log(e1) - math.log(e0)))
            return math.exp(math.log(nf0)
                            + s * (math.log(err_target) - math.log(e0)))
    return None


def main() -> None:
    print(HEADER)
    prob = gbm_problem(r=R, v=V, dtype=jnp.float32)
    ep = EnsembleProblem(prob, N)
    records = {}

    def fixed(n_steps):
        return solve_ensemble_local(ep, alg="em", ensemble="kernel",
                                    backend="xla", t0=0.0, tf=1.0,
                                    dt0=1.0 / n_steps, n_steps=n_steps,
                                    save_every=n_steps, seed=SEED)

    def adaptive(rtol, est):
        return solve_ensemble_local(ep, alg="em", ensemble="kernel",
                                    backend="xla", t0=0.0, tf=1.0, dt0=0.02,
                                    adaptive=True, rtol=rtol, atol=rtol * 1e-2,
                                    seed=SEED, error_est=est,
                                    brownian_depth=DEPTH)

    for n_steps in (200, 1000):
        f = jax.jit(lambda ns=n_steps: fixed(ns).u_final)
        t = bench(f)
        print(row(f"adaptive_sde/fixed/n={n_steps}", t,
                  f"nf={int(fixed(n_steps).nf)}"))
        records[f"fixed_n{n_steps}"] = {
            "seconds": t, "nf": int(fixed(n_steps).nf)}

    exact = _exact_endpoint(DEPTH, jnp.float32)
    curves = {}
    for est in ("embedded", "doubling"):
        curves[est] = []
        for rtol in RTOLS:
            f = jax.jit(lambda r=rtol, e=est: adaptive(r, e).u_final)
            t = bench(f)
            res = adaptive(rtol, est)
            strong = float(np.sqrt(np.mean(
                (np.asarray(res.u_final) - exact) ** 2)))
            nf = int(res.nf)
            print(row(f"adaptive_sde/{est}/rtol={rtol:g}", t,
                      f"nf={nf} strong_rmse={strong:.2e} naccept_mean="
                      f"{float(np.mean(np.asarray(res.naccept))):.0f}"))
            records[f"{est}_rtol{rtol:g}"] = {
                "seconds": t, "nf": nf, "strong_rmse": strong,
                "naccept_mean": float(np.mean(np.asarray(res.naccept))),
                "nreject_total": int(np.sum(np.asarray(res.nreject))),
                "brownian_depth": DEPTH}
            curves[est].append((nf, strong))

    # matched-accuracy work ratio: at each accuracy DOUBLING achieves, how
    # much work does the EMBEDDED pair need? (the ISSUE 4 acceptance metric)
    matched = []
    for (nf_d, err_d), rtol in zip(curves["doubling"], RTOLS):
        nf_e = _nf_at(err_d, curves["embedded"])
        if nf_e is None:
            continue
        matched.append({"doubling_rtol": rtol, "strong_rmse": err_d,
                        "nf_doubling": nf_d,
                        "nf_embedded_interp": round(nf_e),
                        "nf_ratio": round(nf_d / nf_e, 3)})
        print(row(f"adaptive_sde/matched/rmse={err_d:.2e}", 0.0,
                  f"nf_doubling={nf_d} nf_embedded~{nf_e:.0f} "
                  f"ratio={nf_d / nf_e:.2f}"))
    best = max((m["nf_ratio"] for m in matched), default=None)
    summary = {"criterion": "embedded needs >=1.5x fewer drift evals than "
                            "doubling at matched strong error",
               "best_nf_ratio": best,
               "pass": bool(best is not None and best >= 1.5)}

    os.makedirs("results", exist_ok=True)
    out = os.path.join("results", "BENCH_adaptive_sde.json")
    with open(out, "w") as fp:
        json.dump({"N": N, "problem": "gbm(r=1.5,v=0.2)", "seed": SEED,
                   "brownian_depth": DEPTH, "records": records,
                   "matched": matched, "summary": summary},
                  fp, indent=2, sort_keys=True)
    print(f"# wrote {out}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
