"""§6.3: distributed ensemble scaling (the 1-billion-ODE MPI demo).

Two parts:
  * measured: shard_map ensemble solve on the local mesh (1 device here) with
    increasing N — per-trajectory cost must stay flat (weak scaling within a
    shard; there are ZERO collectives in the solve, so cross-shard scaling is
    communication-free by construction).
  * compiled: reads the dry-run record of the 2^30-trajectory cell on the
    512-chip mesh and reports its per-device roofline terms (the deployment
    claim; produced by launch/dryrun.py --ode).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from repro.configs.de_problems import lorenz_ensemble
from repro.core.api import solve_ensemble
from repro.launch.mesh import make_local_mesh

from .common import HEADER, bench, row


def main() -> None:
    print(HEADER)
    mesh = make_local_mesh()
    for N in (1024, 4096, 16384):
        ep = lorenz_ensemble(N, dtype=jnp.float32)

        def run():
            return solve_ensemble(ep, mesh=mesh, shard_axes=("data",),
                                  ensemble="kernel", adaptive=False,
                                  dt0=1e-3, t0=0.0, tf=1.0,
                                  save_every=1000, lane_tile=1024).u_final

        t = bench(jax.jit(run))
        print(row(f"mpi/local/N={N}", t, f"{N / t:.0f} traj_per_s"))

    for rec_name in ("ode_single", "ode_multi"):
        path = os.path.join("results", f"{rec_name}.json")
        if os.path.exists(path):
            with open(path) as f:
                rec = json.load(f)
            if rec.get("ok"):
                print(row(f"mpi/dryrun/{rec_name}", 0.0,
                          f"devices={rec['n_devices']} "
                          f"collective_bytes={rec['collective_bytes']}"))


if __name__ == "__main__":
    main()
