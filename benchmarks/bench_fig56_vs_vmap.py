"""Figs. 5/6 + Tables 2/3: kernel-generation vs vectorized-map-class solvers.

The paper benchmarks DiffEqGPU's kernel against Diffrax (JAX vmap) and
torchdiffeq (PyTorch). Here the vmap-class baseline IS jax vmap-of-solver —
the literal construction Diffrax uses — plus the eager array mode standing in
for torch-style dispatch. Two structural effects are measured:

  * lock-step termination (vmap pays max-steps-of-any across the WHOLE batch;
    kernel tiles retire per-tile) — isolated by a heterogeneous ensemble and
    reported as the work ratio nf_vmap/nf_kernel;
  * dispatch overhead (eager) — the dominant term in the paper's 20-100x.

The heterogeneous sweep over N feeds the kernel-over-vmap crossover into
results/BENCH_crossover.json (section "fig56"; `bench_fig4_crossover.py`
owns "fig4"/"rober_w_reuse" of the same artifact).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core import EnsembleProblem
from repro.configs.de_problems import lorenz_problem
from repro.core.ensemble import solve_ensemble_local

from .common import HEADER, bench_stats, row, update_results_json

N = 1024
SWEEP_NS = (64, 256, 1024)
OUT = os.path.join("results", "BENCH_crossover.json")
REPEATS = 3


def hetero_ensemble(N):
    """rho spread over (0, 350): wildly different step-count demands."""
    prob = lorenz_problem(jnp.float32)
    rho = jnp.concatenate([jnp.linspace(0.0, 21.0, N - N // 8,
                                        dtype=jnp.float32),
                           jnp.linspace(150.0, 350.0, N // 8,
                                        dtype=jnp.float32)])
    ps = jnp.stack([jnp.full((N,), 10.0), rho, jnp.full((N,), 8.0 / 3.0)],
                   axis=1)
    return EnsembleProblem(prob, N, ps=ps)


def main() -> None:
    print(HEADER)
    saveat = jnp.asarray([1.0], jnp.float32)
    record = {}
    for adaptive in (False, True):
        tag = "adaptive" if adaptive else "fixed"
        ep = hetero_ensemble(N)

        def run(ensemble, _ep=ep, **kw):
            return solve_ensemble_local(
                _ep, ensemble=ensemble, t0=0.0, tf=1.0, dt0=1e-3,
                saveat=saveat if adaptive else None, adaptive=adaptive,
                rtol=1e-6, atol=1e-6, save_every=1000, **kw)

        s_ker = bench_stats(
            jax.jit(lambda: run("kernel", lane_tile=128).u_final),
            repeats=REPEATS)
        s_vmap = bench_stats(jax.jit(lambda: run("vmap").u_final),
                             repeats=REPEATS)
        t_ker, t_vmap = s_ker["median"], s_vmap["median"]
        print(row(f"fig56/{tag}/kernel", t_ker, "1.0x"))
        print(row(f"fig56/{tag}/vmap_diffrax_class", t_vmap,
                  f"{t_vmap / t_ker:.2f}x"))
        entry = {"kernel": {k: s_ker[k] for k in ("best", "median")},
                 "vmap": {k: s_vmap[k] for k in ("best", "median")},
                 "vmap_over_kernel": t_vmap / t_ker}
        if adaptive:
            r_k = run("kernel", lane_tile=128)
            r_v = run("vmap")
            # lock-step termination work amplification (RHS evals)
            wr = float(r_v.nf) / float(r_k.nf)
            print(row(f"fig56/{tag}/work_ratio", 0.0,
                      f"nf_vmap/nf_kernel={wr:.2f}"))
            entry["work_ratio_nf"] = wr
        t_eager = bench_stats(lambda: run("array_eager").u_final,
                              repeats=1)["median"]
        print(row(f"fig56/{tag}/eager_torch_class", t_eager,
                  f"{t_eager / t_ker:.1f}x"))
        entry["eager_over_kernel"] = t_eager / t_ker
        record[tag] = entry

    # kernel-over-vmap crossover in N on the heterogeneous adaptive workload
    sweep = {}
    crossover = None
    for n in SWEEP_NS:
        epn = hetero_ensemble(n)

        def runn(ensemble, **kw):
            return solve_ensemble_local(
                epn, ensemble=ensemble, t0=0.0, tf=1.0, dt0=1e-3,
                saveat=saveat, adaptive=True, rtol=1e-6, atol=1e-6,
                **kw).u_final

        tk = bench_stats(jax.jit(lambda: runn("kernel", lane_tile=128)),
                         repeats=REPEATS)["median"]
        tv = bench_stats(jax.jit(lambda: runn("vmap")),
                         repeats=REPEATS)["median"]
        sweep[str(n)] = {"kernel": tk, "vmap": tv}
        print(row(f"fig56/sweep/N={n}", tk, f"vmap={tv * 1e6:.1f}us"))
        if crossover is None and tk < tv:
            crossover = n
    record["hetero_sweep"] = sweep
    record["kernel_over_vmap_crossover"] = crossover
    update_results_json(OUT, "fig56", record)


if __name__ == "__main__":
    main()
