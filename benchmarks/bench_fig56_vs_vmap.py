"""Figs. 5/6 + Tables 2/3: kernel-generation vs vectorized-map-class solvers.

The paper benchmarks DiffEqGPU's kernel against Diffrax (JAX vmap) and
torchdiffeq (PyTorch). Here the vmap-class baseline IS jax vmap-of-solver —
the literal construction Diffrax uses — plus the eager array mode standing in
for torch-style dispatch. Two structural effects are measured:

  * lock-step termination (vmap pays max-steps-of-any across the WHOLE batch;
    kernel tiles retire per-tile) — isolated by a heterogeneous ensemble and
    reported as the work ratio nf_vmap/nf_kernel;
  * dispatch overhead (eager) — the dominant term in the paper's 20-100x.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem
from repro.configs.de_problems import lorenz_problem
from repro.core.ensemble import solve_ensemble_local

from .common import HEADER, bench, row

N = 1024


def hetero_ensemble(N):
    """rho spread over (0, 350): wildly different step-count demands."""
    prob = lorenz_problem(jnp.float32)
    rho = jnp.concatenate([jnp.linspace(0.0, 21.0, N - N // 8,
                                        dtype=jnp.float32),
                           jnp.linspace(150.0, 350.0, N // 8,
                                        dtype=jnp.float32)])
    ps = jnp.stack([jnp.full((N,), 10.0), rho, jnp.full((N,), 8.0 / 3.0)],
                   axis=1)
    return EnsembleProblem(prob, N, ps=ps)


def main() -> None:
    print(HEADER)
    saveat = jnp.asarray([1.0], jnp.float32)
    for adaptive in (False, True):
        tag = "adaptive" if adaptive else "fixed"
        ep = hetero_ensemble(N)

        def run(ensemble, **kw):
            return solve_ensemble_local(
                ep, ensemble=ensemble, t0=0.0, tf=1.0, dt0=1e-3,
                saveat=saveat if adaptive else None, adaptive=adaptive,
                rtol=1e-6, atol=1e-6, save_every=1000, **kw)

        t_ker = bench(jax.jit(lambda: run("kernel", lane_tile=128).u_final))
        t_vmap = bench(jax.jit(lambda: run("vmap").u_final))
        print(row(f"fig56/{tag}/kernel", t_ker, "1.0x"))
        print(row(f"fig56/{tag}/vmap_diffrax_class", t_vmap,
                  f"{t_vmap / t_ker:.2f}x"))
        if adaptive:
            r_k = run("kernel", lane_tile=128)
            r_v = run("vmap")
            # lock-step termination work amplification (RHS evals)
            print(row(f"fig56/{tag}/work_ratio", 0.0,
                      f"nf_vmap/nf_kernel={float(r_v.nf)/float(r_k.nf):.2f}"))
        t_eager = bench(lambda: run("array_eager").u_final, repeats=1)
        print(row(f"fig56/{tag}/eager_torch_class", t_eager,
                  f"{t_eager / t_ker:.1f}x"))


if __name__ == "__main__":
    main()
