"""Adjoint economics: checkpointed discrete adjoint vs naive full-unroll
reverse AD (§6.6 tentpole).

Reverse-mode through a solver must store (or recompute) every accepted step.
The front door's ``sensitivity="adjoint"`` stores one carry per
sqrt(n_steps)-sized segment and recomputes stages inside segments
(`repro.core.loops`); the naive alternative differentiates the plain scan and
stores every stage of every step.  This bench measures BOTH costs of that
choice on a long fixed-dt Lorenz ensemble solve:

  * wall time per gradient (warm, best-of-repeats — `benchmarks.common`);
  * the XLA compiled-memory proxy (`compile().memory_analysis()` temp bytes)
    for the backward pass — the number that decides whether a long horizon
    fits on an accelerator at all;

plus the adaptive-path adjoint (bounded loop, probe-sized attempt bound) so
the paper-style workflow is timed end to end.  Writes
results/BENCH_gradients.json for CI diffing.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from .common import HEADER, bench, row

N, N_STEPS = 64, 4096
RESULTS = os.path.join(os.path.dirname(__file__), os.pardir, "results",
                       "BENCH_gradients.json")


def _temp_bytes(jitted, *args):
    try:
        mem = jitted.lower(*args).compile().memory_analysis()
        if mem is None:
            return None
        return int(mem.temp_size_in_bytes)
    except Exception:                      # pragma: no cover - backend quirk
        return None


def main() -> None:
    jax.config.update("jax_enable_x64", True)
    from repro.core import EnsembleProblem, solve_ensemble_local
    from repro.core.sensitivity import suggest_adjoint_steps
    from repro.core.tableaus import get_tableau
    from repro.core.solvers import solve_fixed
    from repro.configs.de_problems import lorenz_problem

    print(HEADER)
    prob = lorenz_problem(jnp.float64)
    rng = np.random.default_rng(0)
    u0s = jnp.asarray(np.array([-8.0, 7.0, 27.0])
                      + 0.1 * rng.standard_normal((N, 3)))
    ps = jnp.asarray(np.array([10.0, 28.0, 8.0 / 3.0])
                     + 0.05 * rng.standard_normal((N, 3)))
    dt = 1.0 / N_STEPS
    records = {"N": N, "n_steps": N_STEPS}

    # --- fixed-dt horizon: checkpointed adjoint vs naive unrolled reverse --
    def front_door_loss(p, checkpoint_every=None):
        ep = EnsembleProblem(prob, N, u0s=u0s, ps=p)
        res = solve_ensemble_local(ep, alg="tsit5", ensemble="kernel",
                                   backend="xla", t0=0.0, tf=1.0,
                                   adaptive=False, n_steps=N_STEPS,
                                   save_every=N_STEPS, sensitivity="adjoint",
                                   checkpoint_every=checkpoint_every)
        return jnp.sum(res.u_final ** 2)

    tab = get_tableau("tsit5")

    def naive_loss(p):
        # plain differentiable scan, NO remat: stores every stage of every
        # step on the reverse pass — the O(n_steps) baseline
        res = solve_fixed(prob.f, tab, u0s.T, p.T, 0.0, dt, N_STEPS,
                          save_every=N_STEPS)
        return jnp.sum(res.u_final ** 2)

    variants = {
        "adjoint_checkpointed": jax.jit(jax.grad(front_door_loss)),
        "reverse_unrolled": jax.jit(jax.grad(naive_loss)),
    }
    for name, fn in variants.items():
        secs = bench(fn, ps, repeats=3)
        temp = _temp_bytes(fn, ps)
        records[name] = {"seconds": secs, "temp_bytes": temp}
        print(row(f"grad_fixed_{name}", secs,
                  f"temp={temp if temp is not None else 'n/a'}B"))
    ck, un = records["adjoint_checkpointed"], records["reverse_unrolled"]
    if ck["temp_bytes"] and un["temp_bytes"]:
        records["temp_ratio_unrolled_over_checkpointed"] = (
            un["temp_bytes"] / ck["temp_bytes"])
        print(row("grad_fixed_temp_ratio", 0.0,
                  f"{records['temp_ratio_unrolled_over_checkpointed']:.1f}x"
                  " less backward memory (checkpointed)"))

    # --- adaptive horizon: the probe + bounded-adjoint workflow ------------
    akw = dict(alg="tsit5", ensemble="kernel", backend="xla", t0=0.0, tf=1.0,
               dt0=1e-2, rtol=1e-8, atol=1e-8, saveat=jnp.asarray([1.0]))
    ep = EnsembleProblem(prob, N, u0s=u0s, ps=ps)
    bound = suggest_adjoint_steps(ep, **akw)
    records["adaptive_bound"] = int(bound)

    def adaptive_loss(p):
        sub = EnsembleProblem(prob, N, u0s=u0s, ps=p)
        res = solve_ensemble_local(sub, sensitivity="adjoint",
                                   adjoint_steps=bound, **akw)
        return jnp.sum(res.u_final ** 2)

    fwd = jax.jit(lambda p: adaptive_loss(p))
    grad = jax.jit(jax.grad(adaptive_loss))
    t_fwd = bench(fwd, ps, repeats=3)
    t_grad = bench(grad, ps, repeats=3)
    records["adaptive"] = {"forward_seconds": t_fwd, "grad_seconds": t_grad,
                           "grad_over_forward": t_grad / t_fwd}
    print(row("grad_adaptive_forward", t_fwd, f"bound={bound}"))
    print(row("grad_adaptive_vjp", t_grad,
              f"{t_grad / t_fwd:.1f}x forward cost"))

    os.makedirs(os.path.dirname(RESULTS), exist_ok=True)
    with open(RESULTS, "w") as fh:
        json.dump(records, fh, indent=1, sort_keys=True)
    print(f"# wrote {os.path.relpath(RESULTS)}")


if __name__ == "__main__":
    main()
