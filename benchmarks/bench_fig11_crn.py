"""Figs. 10/11: chemical-reaction-network (sigma-factor) SDE parameter sweep.

4 states x 8 Wiener processes (general noise), parameters sampled over the
paper's Table-4 ranges — the paper's real case study for >1M-trajectory
parameter sweeps. Reports throughput + weak-order-2 (platen) vs EM cost.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem
from repro.configs.de_problems import crn_problem
from repro.core.sde import solve_sde_ensemble

from .common import HEADER, bench, row


def crn_sweep_ensemble(N, key):
    prob = crn_problem(tspan=(0.0, 10.0), dtype=jnp.float32)
    lo = jnp.asarray([0.1, 0.1, 0.1, 0.01, 2.0, 0.001])
    hi = jnp.asarray([100.0, 100.0, 100.0, 0.2, 4.0, 0.1])
    u = jax.random.uniform(key, (N, 6))
    ps = lo + u * (hi - lo)
    u0s = jnp.broadcast_to(ps[:, 3:4], (N, 4))  # u0 = v0 per the paper
    return EnsembleProblem(prob, N, u0s=u0s, ps=ps)


def main() -> None:
    print(HEADER)
    key = jax.random.PRNGKey(1)
    n_steps = 100  # dt=0.1 over (0, 10) — scaled-down span for CPU
    for N in (256, 1024, 4096):
        ep = crn_sweep_ensemble(N, key)

        def run(method):
            return solve_sde_ensemble(ep, key, 0.1, n_steps, method=method,
                                      ensemble="kernel",
                                      save_every=n_steps).u_final

        t_em = bench(jax.jit(lambda: run("em")))
        print(row(f"fig11/em/N={N}", t_em, f"{N / t_em:.0f} traj_per_s"))
    out = jax.jit(lambda: run("em"))()
    print(row("fig11/finite_fraction", 0.0,
              f"{float(jnp.mean(jnp.isfinite(out))):.3f}"))


if __name__ == "__main__":
    main()
