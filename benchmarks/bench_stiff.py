"""Stiff work-precision: Rodas4 / Rodas5P / Rosenbrock23 on ROBER (§5.1.3).

The paper's stiff story (GPURosenbrock23 / GPURodas4 / GPURodas5P) measured
here as a work-precision sweep on Robertson's kinetics: for each method and
tolerance, wall time, RHS-evaluation work (nf), accepted/rejected steps, and
the final-state relative error against a tight Rodas5P reference solve.  The
fused-kernel lanes strategy is compared against the vmap-XLA baseline (the
paper's Fig. 5/6 axis, restricted to the stiff family), and the analytic-
Jacobian hook (`ODEProblem.jac`) against the jacfwd fallback.

ROBER spans ~9 orders of magnitude in its rate constants, so the benchmark
force-enables float64 (jax_enable_x64) — in f32 the sweep is meaningless.

Writes a machine-readable record to results/BENCH_stiff.json
(`benchmarks/run.py --only stiff`; `--dry` just imports and checks this
entry point).
"""
from __future__ import annotations

import json
import os

import jax


N, TSPAN, DT0 = 32, (0.0, 1e4), 1e-6
RTOLS = (1e-4, 1e-6, 1e-8)
METHODS = ("rosenbrock23", "rodas4", "rodas5p")


def main() -> None:
    # force f64 for the sweep, but restore the previous setting on exit so
    # later modules in a full `benchmarks/run.py` pass keep their f32 baseline
    prev_x64 = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        _main_x64()
    finally:
        jax.config.update("jax_enable_x64", prev_x64)


def _main_x64() -> None:
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.de_problems import rober_ensemble
    from repro.core import solve_ensemble_local

    from .common import HEADER, bench, row

    print(HEADER)
    ens = rober_ensemble(N, tspan=TSPAN)
    ens_ad = rober_ensemble(N, tspan=TSPAN, analytic_jac=False)

    def solve(alg, strategy, rtol, ep=ens):
        return solve_ensemble_local(
            ep, alg=alg, ensemble=strategy, backend="xla", dt0=DT0,
            rtol=rtol, atol=rtol * 1e-2)

    ref = np.asarray(solve("rodas5p", "kernel", 1e-10).u_final)
    scale = np.abs(ref) + 1e-30
    records = {}

    def record(tag, alg, strategy, rtol, ep=ens):
        fn = jax.jit(lambda: solve(alg, strategy, rtol, ep).u_final)
        secs = bench(fn)
        res = solve(alg, strategy, rtol, ep)
        err = float(np.max(np.abs(np.asarray(res.u_final) - ref) / scale))
        print(row(f"stiff/{tag}", secs,
                  f"err={err:.2e} nf={int(res.nf)} "
                  f"naccept={int(np.max(np.asarray(res.naccept)))}"))
        records[tag] = {
            "seconds": secs, "err": err, "nf": int(res.nf),
            "naccept_max": int(np.max(np.asarray(res.naccept))),
            "nreject_total": int(np.sum(np.asarray(res.nreject)))}

    for alg in METHODS:
        for rtol in RTOLS:
            record(f"{alg}/kernel/rtol={rtol:g}", alg, "kernel", rtol)
    # the vmap-XLA baseline axis (masked lock-step over the whole batch)
    for rtol in RTOLS:
        record(f"rodas4/vmap/rtol={rtol:g}", "rodas4", "vmap", rtol)
    # analytic-Jacobian hook vs the jacfwd fallback (same method/tolerance)
    record("rodas4/kernel/jacfwd/rtol=1e-6", "rodas4", "kernel", 1e-6,
           ep=ens_ad)

    os.makedirs("results", exist_ok=True)
    out = os.path.join("results", "BENCH_stiff.json")
    with open(out, "w") as fp:
        json.dump({"N": N, "problem": f"rober(tspan={TSPAN})",
                   "reference": "rodas5p kernel rtol=1e-10",
                   "records": records}, fp, indent=2, sort_keys=True)
    print(f"# wrote {out}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
