"""Stiff work-precision: Rodas4 / Rodas5P / Rosenbrock23 on ROBER (§5.1.3).

The paper's stiff story (GPURosenbrock23 / GPURodas4 / GPURodas5P) measured
here as a work-precision sweep on Robertson's kinetics: for each method and
tolerance, wall time, RHS-evaluation work (nf), Jacobian/factorization work
(njac/nfact), accepted/rejected steps, and the final-state relative error
against a tight Rodas5P reference solve.  The fused-kernel lanes strategy is
compared against the vmap-XLA baseline (the paper's Fig. 5/6 axis, restricted
to the stiff family), the analytic-Jacobian hook (`ODEProblem.jac`) against
the jacfwd fallback, and — the lazy-W hot path — `w_reuse=True` (Jacobian &
LU(W) reuse across steps under the `WReusePolicy` freshness controller, with
extrapolated-secant touch-ups) against today's eager every-step behaviour, on
ROBER and OREGO ensembles.

The acceptance summary interpolates the eager work-precision curve at the
reuse run's achieved error (matched accuracy, log-log), comparing total
rhs+jac work units  nf + n·njac  and raw Jacobian counts.

ROBER spans ~9 orders of magnitude in its rate constants, so the benchmark
force-enables float64 (jax_enable_x64) — in f32 the sweep is meaningless.

Writes a machine-readable record to results/BENCH_stiff.json
(`benchmarks/run.py --only stiff`; `--dry` just imports and checks this
entry point).
"""
from __future__ import annotations

import json
import os

import jax


N, TSPAN, DT0 = 32, (0.0, 1e4), 1e-6
RTOLS = (1e-4, 1e-6, 1e-8)
METHODS = ("rosenbrock23", "rodas4", "rodas5p")
N_STATE = 3                      # ROBER/OREGO state dim: jac ≈ n rhs units
REUSE_METHODS = ("rosenbrock23", "rodas4")   # lazy-W A/B sweep


def main() -> None:
    # force f64 for the sweep, but restore the previous setting on exit so
    # later modules in a full `benchmarks/run.py` pass keep their f32 baseline
    prev_x64 = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        _main_x64()
    finally:
        jax.config.update("jax_enable_x64", prev_x64)


def _interp_loglog(x, xs, ys):
    """log-log interpolation of the (xs, ys) work-precision curve at x."""
    import numpy as np
    lx, lxs, lys = np.log(x), np.log(xs), np.log(ys)
    order = np.argsort(lxs)
    return float(np.exp(np.interp(lx, lxs[order], lys[order])))


def _main_x64() -> None:
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.de_problems import EnsembleProblem, orego_problem, \
        rober_ensemble
    from repro.core import solve_ensemble_local

    from .common import HEADER, bench, row

    print(HEADER)
    ens = rober_ensemble(N, tspan=TSPAN)
    ens_ad = rober_ensemble(N, tspan=TSPAN, analytic_jac=False)
    ens_orego = EnsembleProblem(orego_problem(), 8)

    def solve(alg, strategy, rtol, ep=ens, w_reuse=None, dt0=DT0):
        return solve_ensemble_local(
            ep, alg=alg, ensemble=strategy, backend="xla", dt0=dt0,
            rtol=rtol, atol=rtol * 1e-2, w_reuse=w_reuse)

    ref = np.asarray(solve("rodas5p", "kernel", 1e-10).u_final)
    scale = np.abs(ref) + 1e-30
    ref_orego = np.asarray(solve("rodas5p", "kernel", 1e-10, ep=ens_orego,
                                 dt0=1e-4).u_final)
    scale_orego = np.abs(ref_orego) + 1e-30
    records = {}

    def record(tag, alg, strategy, rtol, ep=ens, w_reuse=None, dt0=DT0,
               rf=None, sc=None):
        rf = ref if rf is None else rf
        sc = scale if sc is None else sc
        fn = jax.jit(
            lambda: solve(alg, strategy, rtol, ep, w_reuse, dt0).u_final)
        secs = bench(fn)
        res = solve(alg, strategy, rtol, ep, w_reuse, dt0)
        err = float(np.max(np.abs(np.asarray(res.u_final) - rf) / sc))
        njac, nfact = int(res.njac), int(res.nfact)
        work = int(res.nf) + N_STATE * njac
        print(row(f"stiff/{tag}", secs,
                  f"err={err:.2e} nf={int(res.nf)} njac={njac} "
                  f"naccept={int(np.max(np.asarray(res.naccept)))}"))
        records[tag] = {
            "seconds": secs, "err": err, "nf": int(res.nf),
            "njac": njac, "nfact": nfact, "work_units": work,
            "naccept_max": int(np.max(np.asarray(res.naccept))),
            "nreject_total": int(np.sum(np.asarray(res.nreject)))}
        return records[tag]

    for alg in METHODS:
        for rtol in RTOLS:
            record(f"{alg}/kernel/rtol={rtol:g}", alg, "kernel", rtol)
    # the vmap-XLA baseline axis (masked lock-step over the whole batch)
    for rtol in RTOLS:
        record(f"rodas4/vmap/rtol={rtol:g}", "rodas4", "vmap", rtol)
    # analytic-Jacobian hook vs the jacfwd fallback (same method/tolerance)
    record("rodas4/kernel/jacfwd/rtol=1e-6", "rodas4", "kernel", 1e-6,
           ep=ens_ad)

    # ---- lazy-W reuse-on/off sweep (ISSUE 5 tentpole) ----------------------
    # same strategy/backend, w_reuse on vs off; matched-accuracy comparison
    # via log-log interpolation of the eager curve at the reuse run's error
    acceptance = {}
    for alg in REUSE_METHODS:
        on_recs = {}
        for rtol in RTOLS:
            on_recs[rtol] = record(f"{alg}/kernel/w_reuse/rtol={rtol:g}",
                                   alg, "kernel", rtol, w_reuse=True)
        off = [records[f"{alg}/kernel/rtol={r:g}"] for r in RTOLS]
        errs = np.asarray([o["err"] for o in off])
        for rtol in (1e-6, 1e-8):
            on = on_recs[rtol]
            if not (errs.min() <= on["err"] <= errs.max()):
                # outside the eager curve's hull: np.interp would CLAMP to
                # the endpoint and silently flatter the ratio — skip instead
                continue
            work_off = _interp_loglog(
                on["err"], errs, np.asarray([o["work_units"] for o in off]))
            njac_off = _interp_loglog(
                on["err"], errs, np.asarray([o["njac"] for o in off]))
            acceptance[f"{alg}/rtol={rtol:g}"] = {
                "err": on["err"],
                "njac_ratio_matched": njac_off / max(on["njac"], 1),
                "work_ratio_matched": work_off / on["work_units"],
                "njac_ratio_same_rtol":
                    records[f"{alg}/kernel/rtol={rtol:g}"]["njac"]
                    / max(on["njac"], 1),
                "work_ratio_same_rtol":
                    records[f"{alg}/kernel/rtol={rtol:g}"]["work_units"]
                    / on["work_units"]}
    # OREGO: the second stiff ensemble of the sweep (relaxation oscillator)
    for w, tag in ((None, "orego/kernel/rtol=1e-6"),
                   (True, "orego/kernel/w_reuse/rtol=1e-6")):
        record(tag, "rosenbrock23", "kernel", 1e-6, ep=ens_orego,
               w_reuse=w, dt0=1e-4, rf=ref_orego, sc=scale_orego)
    best = max(acceptance.values(),
               key=lambda a: a["work_ratio_matched"]) if acceptance else None
    passed = bool(best and best["njac_ratio_matched"] >= 2.0
                  and best["work_ratio_matched"] >= 1.3)
    print(f"# lazy-W acceptance: {json.dumps(acceptance, sort_keys=True)}")
    print(f"# lazy-W bar (njac>=2x, work>=1.3x, matched accuracy): "
          f"{'PASS' if passed else 'FAIL'}")

    os.makedirs("results", exist_ok=True)
    out = os.path.join("results", "BENCH_stiff.json")
    with open(out, "w") as fp:
        json.dump({"N": N, "problem": f"rober(tspan={TSPAN})",
                   "reference": "rodas5p kernel rtol=1e-10",
                   "work_units": f"nf + {N_STATE}*njac",
                   "records": records,
                   "w_reuse_acceptance": acceptance,
                   "w_reuse_bar_passed": passed}, fp, indent=2,
                  sort_keys=True)
    print(f"# wrote {out}")


if __name__ == "__main__":
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    main()
