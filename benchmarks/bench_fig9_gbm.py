"""Fig. 9: SDE ensembles (geometric Brownian motion / asset pricing).

Kernel-fused SDE ensemble vs vmap-per-trajectory vs trajectory count, plus
Monte-Carlo moment accuracy against the analytic GBM mean (the quantity the
ensemble exists to estimate, §6.8.1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import EnsembleProblem
from repro.configs.de_problems import gbm_problem
from repro.core.sde import solve_sde_ensemble

from .common import HEADER, bench, row

NS = (256, 1024, 4096, 16384)


def main() -> None:
    print(HEADER)
    prob = gbm_problem(r=1.5, v=0.2, dtype=jnp.float32)
    n_steps = 200
    for N in NS:
        ep = EnsembleProblem(prob, N)
        key = jax.random.PRNGKey(0)

        def kern():
            return solve_sde_ensemble(ep, key, 1.0 / n_steps, n_steps,
                                      method="em", ensemble="kernel",
                                      save_every=n_steps).u_final

        def vm():
            return solve_sde_ensemble(ep, key, 1.0 / n_steps, n_steps,
                                      method="em", ensemble="vmap",
                                      save_every=n_steps).u_final

        t_k = bench(jax.jit(kern))
        print(row(f"fig9/kernel/N={N}", t_k, f"{N / t_k:.0f} traj_per_s"))
        if N <= 4096:
            t_v = bench(jax.jit(vm))
            print(row(f"fig9/vmap/N={N}", t_v, f"{t_v / t_k:.2f}x"))
    # moment accuracy at the largest N
    X = np.asarray(jax.jit(kern)())[:, 0]
    exact = 0.1 * np.exp(1.5)
    print(row("fig9/mean_rel_err", 0.0,
              f"{abs(X.mean() - exact) / exact:.2e}"))


if __name__ == "__main__":
    main()
