"""Table 1: mean slowdown of each strategy relative to the kernel ensemble,
fixed vs adaptive time-stepping (kernel = 1.0x by construction).

The paper's Table 1 (GPU): kernel 1.0x, array 48.2x (adaptive) / 377.6x
(fixed), CPU 22.2x / 110.3x. Our analogue adds the honest eager-dispatch
array mode (the PyTorch-style per-op launch overhead the paper measures).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.de_problems import lorenz_ensemble
from repro.core.ensemble import solve_ensemble_local

from .common import HEADER, bench, row

N = 2048


def main() -> None:
    print(HEADER)
    saveat = jnp.linspace(0.0, 1.0, 5, dtype=jnp.float32)
    for adaptive in (False, True):
        tag = "adaptive" if adaptive else "fixed"
        ep = lorenz_ensemble(N, dtype=jnp.float32)

        def run(ensemble, **kw):
            return solve_ensemble_local(
                ep, ensemble=ensemble, t0=0.0, tf=1.0, dt0=1e-3,
                saveat=saveat if adaptive else None, adaptive=adaptive,
                rtol=1e-6, atol=1e-6, save_every=250, **kw).u_final

        t_ker = bench(jax.jit(partial(run, "kernel", lane_tile=1024)))
        t_arr = bench(jax.jit(partial(run, "array")))
        # eager array: python-driven per-op dispatch (not jittable by design)
        t_eag = bench(partial(run, "array_eager"), repeats=1)
        print(row(f"table1/{tag}/kernel", t_ker, "1.0x"))
        print(row(f"table1/{tag}/array", t_arr, f"{t_arr / t_ker:.1f}x"))
        print(row(f"table1/{tag}/array_eager", t_eag,
                  f"{t_eag / t_ker:.1f}x"))


if __name__ == "__main__":
    main()
