"""Fig. 4: ODE ensemble solve time vs trajectory count — serial-CPU vs
array-ensemble vs fused-kernel ensemble (vs the vmap baseline), fixed +
adaptive Tsit5 on Lorenz, plus the ROBER stiff/`w_reuse` asymmetry.

Paper claim reproduced: the kernel strategy dominates the array strategy with
a widening gap in N, and parallel ensembling overtakes the serial solve at
modest N. (On 1 CPU core the "GPU" axis is structural: one fused computation
vs per-step dispatched array ops.)

This sweep doubles as the autotuner's ground truth: for every swept N the
`ensemble="auto"` decision (`repro.core.autotune.resolve_auto`, tuned into a
throwaway cache so the run is self-contained) is recorded next to the
measured per-strategy medians, and the crossover N per strategy pair is
written to results/BENCH_crossover.json (sections "fig4" / "rober_w_reuse";
`bench_fig56_vs_vmap.py` owns section "fig56" of the same artifact).
"""
from __future__ import annotations

import os
import tempfile

import jax
import jax.numpy as jnp

from repro.configs.de_problems import lorenz_ensemble, rober_ensemble
from repro.core import get_method
from repro.core.autotune import device_kind, resolve_auto
from repro.core.ensemble import solve_ensemble_local

from .common import HEADER, bench_stats, row, update_results_json

NS = (64, 256, 1024, 4096)
OUT = os.path.join("results", "BENCH_crossover.json")
REPEATS = 3


def _solve(ep, ensemble, adaptive, **kw):
    saveat = jnp.linspace(0.0, 1.0, 5, dtype=jnp.float32)
    return solve_ensemble_local(
        ep, ensemble=ensemble, t0=0.0, tf=1.0, dt0=1e-3,
        saveat=saveat if adaptive else None, adaptive=adaptive,
        rtol=1e-6, atol=1e-6, save_every=250, **kw).u_final


def _crossover(ns, table, slow, fast):
    """Smallest swept N where `fast`'s median beats `slow`'s (None: never)."""
    for N in ns:
        ts, tf_ = table[str(N)].get(slow), table[str(N)].get(fast)
        if ts and tf_ and tf_["median"] < ts["median"]:
            return N
    return None


def _lorenz_sweep(cache: str):
    record = {}
    for adaptive in (False, True):
        tag = "adaptive" if adaptive else "fixed"
        table = {}
        for N in NS:
            ep = lorenz_ensemble(N, dtype=jnp.float32)

            def jit_of(**kw):
                # close over ep (a config dataclass, not a pytree)
                return jax.jit(lambda: _solve(ep, adaptive=adaptive, **kw))

            entry = {}
            if N <= 256:   # serial baseline: 1-lane tiles looped via lax.map
                entry["serial"] = bench_stats(
                    jit_of(ensemble="kernel", lane_tile=1), repeats=REPEATS)
            entry["vmap"] = bench_stats(jit_of(ensemble="vmap"),
                                        repeats=REPEATS)
            entry["array"] = bench_stats(jit_of(ensemble="array"),
                                         repeats=REPEATS)
            entry["kernel"] = bench_stats(
                jit_of(ensemble="kernel", lane_tile=min(N, 1024)),
                repeats=REPEATS)
            for name, st in entry.items():
                st.pop("times", None)
                print(row(f"fig4/{tag}/{name}/N={N}", st["median"],
                          f"{N / st['median']:.0f} traj_per_s"))

            dec = resolve_auto(
                ep, get_method("tsit5"), t0=0.0, tf=1.0, dt0=1e-3,
                saveat=jnp.linspace(0.0, 1.0, 5, dtype=jnp.float32)
                if adaptive else None, adaptive=adaptive, rtol=1e-6,
                atol=1e-6, save_every=250 if not adaptive else 1,
                n_steps=1000 if not adaptive else None, cache_path=cache)
            measured = {k: v["median"] for k, v in entry.items()
                        if k != "serial"}
            winner = min(measured, key=measured.get)
            picked = measured.get(dec.strategy, float("inf"))
            entry["auto"] = {
                "strategy": dec.strategy, "backend": dec.backend,
                "lane_tile": dec.lane_tile, "source": dec.source,
                "measured_winner": winner,
                # within-noise: auto's pick costs <= 1.25x the winner
                "matches_winner": bool(picked <= 1.25 * measured[winner])}
            print(row(f"fig4/{tag}/auto/N={N}", picked,
                      f"picked={dec.strategy}/{dec.backend} "
                      f"winner={winner}"))
            table[str(N)] = entry
        record[tag] = table
        record[f"{tag}_crossover"] = {
            "kernel_over_array": _crossover(NS, table, "array", "kernel"),
            "kernel_over_vmap": _crossover(NS, table, "vmap", "kernel"),
            "array_over_vmap": _crossover(NS, table, "vmap", "array"),
            "parallel_over_serial": _crossover(
                (64, 256), table, "serial", "kernel")}
    return record


def _rober_sweep(cache: str):
    """Stiff asymmetry: with `w_reuse` the refresh is any()-gated on EVERY
    strategy now (the vmap path psum-reduces the gate), but vmap still pays
    lock-step stepping — the tuner should see (and the artifact record)
    kernel/array pulling further ahead when reuse is on."""
    record = {}
    for N in (16, 64):
        ep = rober_ensemble(N)
        entry = {}
        for strategy in ("vmap", "array", "kernel"):
            for wr in (False, True):
                def jit_of(_s=strategy, _w=wr):
                    return jax.jit(lambda: solve_ensemble_local(
                        ep, alg="rodas4", ensemble=_s, t0=0.0, tf=1e3,
                        dt0=1e-6, rtol=1e-6, atol=1e-8,
                        w_reuse=_w).u_final)

                st = bench_stats(jit_of(), repeats=REPEATS)
                st.pop("times", None)
                key = f"{strategy}{'_w_reuse' if wr else ''}"
                entry[key] = st
                print(row(f"fig4/rober/{key}/N={N}", st["median"]))
        dec = resolve_auto(ep, get_method("rodas4"), t0=0.0, tf=1e3,
                           dt0=1e-6, rtol=1e-6, atol=1e-8, w_reuse=True,
                           cache_path=cache)
        entry["auto_w_reuse"] = {"strategy": dec.strategy,
                                 "backend": dec.backend,
                                 "lane_tile": dec.lane_tile,
                                 "source": dec.source}
        record[str(N)] = entry
    return record


def main() -> None:
    print(HEADER)
    # throwaway profile cache: the artifact must reflect THIS machine today,
    # not whatever a previous run persisted
    cache = os.path.join(tempfile.mkdtemp(prefix="repro-bench-"),
                         "autotune.json")
    meta = {"device": device_kind(), "jax": jax.__version__,
            "repeats": REPEATS}
    update_results_json(OUT, "meta", meta)
    update_results_json(OUT, "fig4", _lorenz_sweep(cache))

    prev_x64 = jax.config.read("jax_enable_x64")
    jax.config.update("jax_enable_x64", True)
    try:
        update_results_json(OUT, "rober_w_reuse", _rober_sweep(cache))
    finally:
        jax.config.update("jax_enable_x64", prev_x64)


if __name__ == "__main__":
    main()
