"""Fig. 4: ODE ensemble solve time vs trajectory count — serial-CPU vs
array-ensemble vs fused-kernel ensemble, fixed + adaptive Tsit5 on Lorenz.

Paper claim reproduced: the kernel strategy dominates the array strategy with
a widening gap in N, and parallel ensembling overtakes the serial solve at
modest N. (On 1 CPU core the "GPU" axis is structural: one fused computation
vs per-step dispatched array ops.)
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.de_problems import lorenz_ensemble
from repro.core.ensemble import solve_ensemble_local

from .common import HEADER, bench, row

NS = (64, 256, 1024, 4096)


def _solve(ep, ensemble, adaptive, **kw):
    saveat = jnp.linspace(0.0, 1.0, 5, dtype=jnp.float32)
    return solve_ensemble_local(
        ep, ensemble=ensemble, t0=0.0, tf=1.0, dt0=1e-3,
        saveat=saveat if adaptive else None, adaptive=adaptive,
        rtol=1e-6, atol=1e-6, save_every=250, **kw).u_final


def main() -> None:
    print(HEADER)
    for adaptive in (False, True):
        tag = "adaptive" if adaptive else "fixed"
        for N in NS:
            ep = lorenz_ensemble(N, dtype=jnp.float32)

            def jit_of(**kw):
                # close over ep (a config dataclass, not a pytree)
                return jax.jit(lambda: _solve(ep, adaptive=adaptive, **kw))

            # serial baseline: one-trajectory kernel looped via lax.map tile=1
            t_ser = bench(jit_of(ensemble="kernel", lane_tile=1)) \
                if N <= 256 else float("nan")
            t_arr = bench(jit_of(ensemble="array"))
            t_ker = bench(jit_of(ensemble="kernel", lane_tile=min(N, 1024)))
            if N <= 256:
                print(row(f"fig4/{tag}/serial/N={N}", t_ser,
                          f"{N / t_ser:.0f} traj_per_s"))
            print(row(f"fig4/{tag}/array/N={N}", t_arr,
                      f"{N / t_arr:.0f} traj_per_s"))
            print(row(f"fig4/{tag}/kernel/N={N}", t_ker,
                      f"{N / t_ker:.0f} traj_per_s"))


if __name__ == "__main__":
    main()
