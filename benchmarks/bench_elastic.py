"""Elastic-supervisor weak scaling: problems/sec vs shard count, with and
without injected failures (docs/architecture.md "Elasticity & fault
tolerance").

Workload: a Lorenz parameter sweep at a FIXED lane count per shard (weak
scaling — shard counts 1/2/4 solve 8/16/32 lanes), driven end to end by
`ElasticSupervisor`: bounded segments, a snapshot every epoch, re-shard on
failure.  Each shard count is measured twice:

  * clean        — no failures injected.
  * one_failure  — a `ChaosMonkey`-scheduled shard kill at epoch 2: the dead
    shard's tiles roll back to the epoch-1 snapshot and replay on the
    survivors.

The figure of merit is the throughput ratio one_failure/clean at the same
shard count (bar: >= 0.8x) — the price of a failure is bounded by one
snapshot interval of replay for the dead shard's tiles, NOT a run restart.
Compilation is excluded (an untimed warmup run per supervisor; `run()` is
re-runnable and reuses the compiled engine), so the ratio measures rollback
+ re-shard + replay overhead only.  Timings are single-core CPU (the
*structural* claim, not TPU deployment); each variant reports the best of
`REPEATS` runs.

Writes results/BENCH_elastic.json (sections: weak_scaling, summary).
"""
from __future__ import annotations

import tempfile

import jax.numpy as jnp

RATIO_BAR = 0.8
LANES_PER_SHARD = 8
SHARD_COUNTS = (1, 2, 4)
REPEATS = 2


def _timed_run(sup, make_chaos=None):
    """Best wall seconds over REPEATS re-runs of one supervisor.  A fresh
    monkey per repeat — schedule entries fire once by design."""
    best = None
    for _ in range(REPEATS):
        sup.chaos = None if make_chaos is None else make_chaos()
        res = sup.run()
        assert (res.status == 0).all(), "bench run must finish every lane"
        if make_chaos is not None:
            assert len(res.report["failures"]) == 1, res.report["failures"]
        wall = res.report["wall_s"]
        best = wall if best is None else min(best, wall)
    return best


def main() -> None:
    from repro.configs.de_problems import lorenz_ensemble
    from repro.dist.chaos import ChaosMonkey
    from repro.dist.elastic import ElasticSupervisor

    from .common import HEADER, row, update_results_json

    print(HEADER)
    rows = []
    for k in SHARD_COUNTS:
        n = LANES_PER_SHARD * k
        ep = lorenz_ensemble(n, dtype=jnp.float32)
        sup = ElasticSupervisor(
            ep, "tsit5",
            ckpt_dir=tempfile.mkdtemp(prefix="bench_elastic_"),
            n_shards=k, tile_width=4, segment_steps=32, snapshot_every=1,
            t0=0.0, tf=2.0, dt0=1e-2, rtol=1e-6, atol=1e-6,
            backoff_base=0.0)
        sup.run()                    # untimed warmup absorbs compilation
        t_clean = _timed_run(sup)
        # one scheduled kill at epoch 2 — after the first snapshot exists
        t_kill = _timed_run(
            sup, lambda: ChaosMonkey(schedule=[(2, 0, "kill")]))
        pps_clean = n / t_clean
        pps_kill = n / t_kill
        ratio = pps_kill / pps_clean
        rows.append(dict(
            n_shards=k, n_lanes=n,
            clean=dict(wall_s=t_clean, problems_per_s=pps_clean),
            one_failure=dict(wall_s=t_kill, problems_per_s=pps_kill),
            ratio=ratio, bar=RATIO_BAR, meets_bar=bool(ratio >= RATIO_BAR)))
        print(row(f"elastic/shards{k}/clean", t_clean / n,
                  f"{pps_clean:.1f} problems_per_s"))
        print(row(f"elastic/shards{k}/one_failure", t_kill / n,
                  f"{pps_kill:.1f} problems_per_s ratio={ratio:.2f}"))
    path = "results/BENCH_elastic.json"
    update_results_json(path, "weak_scaling", rows)
    min_ratio = min(r["ratio"] for r in rows)
    update_results_json(path, "summary", dict(
        lanes_per_shard=LANES_PER_SHARD, shard_counts=list(SHARD_COUNTS),
        repeats=REPEATS, min_failure_ratio=min_ratio, bar=RATIO_BAR,
        meets_bar=bool(min_ratio >= RATIO_BAR)))


if __name__ == "__main__":
    main()
