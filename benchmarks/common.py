"""Shared benchmark utilities: best-of-k wall timing of jitted callables.

Methodology (paper §6.1 analogue): report the BEST of `repeats` timed calls
after one warmup (compile) call — matching BenchmarkTools.jl's minimum-time
convention the paper uses. All timings are single-core CPU; they measure the
*algorithmic structure* claims (array vs kernel), not TPU deployment (that is
§Roofline's job).
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def bench(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Returns best wall-clock seconds per call (post-warmup)."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


HEADER = "name,us_per_call,derived"
