"""Shared benchmark utilities: wall timing + the crossover-JSON writer.

Methodology (paper §6.1 analogue): one untimed warmup call absorbs
compilation, then `repeats` timed calls with `jax.block_until_ready` INSIDE
the clock; strategies are ranked by the MEDIAN (robust to scheduler noise)
and the BEST is reported as the machine-capability figure (BenchmarkTools.jl's
minimum-time convention the paper uses).  The harness itself lives in
`repro.core.autotune.measure` — the autotuner and every `bench_fig*` script
time through the SAME code, so the profile cache and the paper figures cannot
disagree on methodology.  All timings are single-core CPU; they measure the
*algorithmic structure* claims (array vs kernel), not TPU deployment (that is
§Roofline's job).
"""
from __future__ import annotations

import json
import os
from typing import Callable, Dict

from repro.core.autotune import measure


def bench_stats(fn: Callable, *args, repeats: int = 3, **kw) -> Dict:
    """{"best", "median", "times"} seconds per call (warmup excluded)."""
    return measure(fn, *args, repeats=repeats, **kw)


def bench(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Returns best wall-clock seconds per call (post-warmup)."""
    return bench_stats(fn, *args, repeats=repeats, **kw)["best"]


def bench_median(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Returns median wall-clock seconds per call (warmup excluded)."""
    return bench_stats(fn, *args, repeats=repeats, **kw)["median"]


def row(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds * 1e6:.1f},{derived}"


HEADER = "name,us_per_call,derived"


def update_results_json(path: str, section: str, payload) -> None:
    """Merge `payload` under `section` of a results JSON (e.g.
    results/BENCH_crossover.json) — the fig4/fig56 benches each own a
    section of one shared artifact, so either can run alone."""
    data = {}
    try:
        with open(path) as fh:
            data = json.load(fh)
    except (OSError, ValueError):
        pass
    data[section] = payload
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as fh:
        json.dump(data, fh, indent=1, sort_keys=True)
    print(f"# wrote {path} [{section}]")
