"""Fig. 7: vendor agnosticism -> backend agnosticism.

The paper runs ONE kernel definition on NVIDIA/AMD/Intel/Apple. This repo's
analogue: ONE solver definition instantiated through three backends —
  xla        (CPU execution here; TPU/GPU in deployment)
  pallas     (TPU kernel; validated via interpret mode — timing note only)
  lanes sweep (lane-tile occupancy autotune, the KernelAbstractions analogue)
plus numerical agreement across backends (the actual portability claim).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.de_problems import lorenz_ensemble
from repro.core.ensemble import solve_ensemble_local

from .common import HEADER, bench, row

N = 1024


def main() -> None:
    print(HEADER)
    ep = lorenz_ensemble(N, dtype=jnp.float32)
    saveat = jnp.asarray([1.0], jnp.float32)

    def run(backend, lane_tile):
        return solve_ensemble_local(
            ep, ensemble="kernel", backend=backend, lane_tile=lane_tile,
            t0=0.0, tf=1.0, dt0=1e-3, saveat=saveat, rtol=1e-6, atol=1e-6)

    # lane-tile sweep (occupancy tuning)
    for tile in (64, 256, 1024):
        t = bench(jax.jit(lambda tile=tile: run("xla", tile).u_final))
        print(row(f"fig7/xla/tile={tile}", t, f"{N / t:.0f} traj_per_s"))
    # backend agreement: pallas (interpret) vs xla, small N for speed
    ep_small = lorenz_ensemble(32, dtype=jnp.float32)
    rx = solve_ensemble_local(ep_small, ensemble="kernel", backend="xla",
                              lane_tile=8, t0=0.0, tf=1.0, dt0=1e-3,
                              saveat=saveat, rtol=1e-6, atol=1e-6)
    rp = solve_ensemble_local(ep_small, ensemble="kernel", backend="pallas",
                              lane_tile=8, t0=0.0, tf=1.0, dt0=1e-3,
                              saveat=saveat, rtol=1e-6, atol=1e-6)
    agree = float(jnp.max(jnp.abs(rx.u_final - rp.u_final)))
    print(row("fig7/pallas_vs_xla_agreement", 0.0, f"max_abs_diff={agree:.2e}"))


if __name__ == "__main__":
    main()
